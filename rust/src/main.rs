//! `ftfi` — the leader binary: launcher + CLI over the whole stack.
//!
//! ```text
//! ftfi integrate  --n 5000 --f exp --repeat 8   FTFI vs brute; prepared-plan reuse
//! ftfi integrate  --ensemble-trees 8            FRT/Bartal tree-ensemble route
//! ftfi integrate  --delta-rows 16               sparse-delta vs full re-integration
//! ftfi integrate  --replan-edges 4              in-place edge re-plan vs full rebuild
//! ftfi serve      --requests 500 --batch 8      batched field-integration server
//! ftfi serve      --backend ensemble            serve the tree-ensemble backend
//! ftfi serve      --streaming --sessions 4      per-session sparse-update serving
//! ftfi gw         --n 300                       Gromov–Wasserstein demo
//! ftfi train      --steps 200 --lr 0.01         train TopViT-mini via PJRT [pjrt]
//! ftfi info                                     versions, artifact status
//! ```
//!
//! `serve --streaming` opens `[streaming]`-configured sessions
//! (`--refresh-every R`, `--max-sessions S`) that own a field and its
//! cached integral and answer k-row updates through the delta fast
//! path; `integrate --delta-rows k` compares one such update against a
//! full prepared re-integration. `integrate --replan-edges k` reweights
//! `k` tree edges through the in-place O(log n) re-plan
//! (`TreeFieldIntegrator::replan_edge_prepared`) and compares against a
//! rebuild-from-scratch + re-prepare; `serve --streaming
//! --replan-edges r` additionally streams `r` edge replans (wire opcode
//! 2) through the server. `serve --streaming --wire typed|legacy`
//! selects the checksummed binary protocol (default; seeded-backoff
//! retries on backpressure) or the original float-opcode frames;
//! `--max-pending P` and `--shed-after-ms D` (config:
//! `streaming.max_pending` / `streaming.shed_after_ms`) bound the
//! per-session queue and the queue age before load shedding.
//! `serve --streaming --graphs G` spreads the sessions over `G`
//! distinct graphs opened through the multi-graph plan cache
//! (`--cache-graphs N`, `--cache-bytes-mb B`, config: the `[cache]`
//! section; typed wire only), and `--fuse-updates on|off` toggles the
//! batch-window delta fusion (bit-identical either way).
//!
//! `integrate` and `serve` accept `--threads N` (0 = auto: honour
//! `FTFI_THREADS`, else all cores; 1 = serial) for the parallel
//! integrate / prepare / batch engine — outputs are bit-identical for
//! every setting — and `--precision f64|f32` (config:
//! `integrator.precision`) selecting the compute tier: `f64` is the
//! bit-identical default, `f32` the opt-in serving tier (f32 products,
//! f64 accumulation; tree backend only — the graph/ensemble backends
//! reject it with a typed error) — plus the tree-ensemble knobs `--ensemble-trees M`
//! (0 = single-MST route), `--ensemble-seed S` and
//! `--ensemble-method frt|bartal` (config: the `[ensemble]` section);
//! fixed `(seed, trees)` reproduces bit-identically for any thread
//! count. The `train` command and the `--backend topvit` serve path
//! need the `pjrt` cargo feature (external `xla`/`anyhow` crates);
//! everything else is dependency-free.

use ftfi::bench_util::time_once;
use ftfi::cli::Args;
use ftfi::config::{CacheConfig, Config, EnsembleConfig, IntegratorConfig, StreamingConfig};
use ftfi::coordinator::{
    protocol, retry_with_backoff, BackoffPolicy, BatchExecutor, BatcherConfig, FieldExecutor,
    InferenceServer, MetricsRegistry, PreparedFieldExecutor, RetryStep, ServerError,
    StreamRequest, StreamResponse, StreamingFieldExecutor,
};
use ftfi::ftfi::brute::{BruteForceIntegrator, BruteTreeIntegrator};
use ftfi::ftfi::functions::FDist;
use ftfi::ftfi::{EnsembleFieldIntegrator, FieldIntegrator, TreeFieldIntegrator};
use ftfi::graph::{generators, mst::try_minimum_spanning_tree};
use ftfi::linalg::matrix::Matrix;
use ftfi::ml::rng::Pcg;
use ftfi::ot::gw::{gromov_wasserstein, GwBackend, GwParams};
use ftfi::ot::sinkhorn::uniform_marginal;
use ftfi::WorkPool;
use std::sync::Arc;
use std::time::Duration;

type CliResult = Result<(), Box<dyn std::error::Error>>;

fn main() {
    let args = Args::from_env();
    let result = match args.command.as_deref() {
        Some("integrate") => cmd_integrate(&args),
        Some("train") => cmd_train(&args),
        Some("serve") => cmd_serve(&args),
        Some("gw") => cmd_gw(&args),
        Some("info") => cmd_info(),
        _ => {
            eprintln!(
                "usage: ftfi <integrate|train|serve|gw|info> [--options]\n\
                 see the module docs in rust/src/main.rs"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn parse_f(name: &str, lambda: f64) -> Result<FDist, String> {
    match name {
        "identity" => Ok(FDist::Identity),
        "exp" => Ok(FDist::Exponential { lambda: -lambda, scale: 1.0 }),
        "invquad" => Ok(FDist::inverse_quadratic(lambda)),
        "gauss" => Ok(FDist::gaussian(lambda)),
        "poly" => Ok(FDist::Polynomial(vec![1.0, -lambda, lambda * lambda / 4.0])),
        other => Err(format!("unknown f {other:?} (identity|exp|invquad|gauss|poly)")),
    }
}

/// Resolve the integrator policy from `--config` (the `[integrator]`
/// section) plus direct CLI overrides.
fn integrator_config(args: &Args) -> Result<IntegratorConfig, Box<dyn std::error::Error>> {
    let mut cfg = match args.get("config") {
        Some(path) => IntegratorConfig::from_config(&Config::load(path)?),
        None => IntegratorConfig::default(),
    };
    if let Some(t) = args.get("leaf-threshold") {
        cfg.leaf_threshold = t.parse().map_err(|_| format!("bad --leaf-threshold {t:?}"))?;
    }
    if let Some(s) = args.get("force") {
        cfg.force = Some(s.to_string());
    }
    if let Some(t) = args.get("threads") {
        cfg.threads = t.parse().map_err(|_| format!("bad --threads {t:?}"))?;
    }
    if let Some(p) = args.get("precision") {
        cfg.precision = p.to_string();
    }
    Ok(cfg)
}

/// Resolve the tree-ensemble knobs from `--config` (the `[ensemble]`
/// section) plus direct CLI overrides.
fn ensemble_config(args: &Args) -> Result<EnsembleConfig, Box<dyn std::error::Error>> {
    let mut cfg = match args.get("config") {
        Some(path) => EnsembleConfig::from_config(&Config::load(path)?),
        None => EnsembleConfig::default(),
    };
    if let Some(t) = args.get("ensemble-trees") {
        cfg.trees = t.parse().map_err(|_| format!("bad --ensemble-trees {t:?}"))?;
    }
    if let Some(s) = args.get("ensemble-seed") {
        cfg.seed = s.parse().map_err(|_| format!("bad --ensemble-seed {s:?}"))?;
    }
    if let Some(m) = args.get("ensemble-method") {
        cfg.method = m.to_string();
    }
    Ok(cfg)
}

/// The tree-ensemble route of `integrate`: average FTFI over `m` random
/// FRT/Bartal embeddings and compare against the exact graph-metric
/// integral (brute force) and the single-MST approximation.
fn cmd_integrate_ensemble(args: &Args, ecfg: &EnsembleConfig) -> CliResult {
    let n = args.get_usize("n", 2000);
    let extra = args.get_usize("extra-edges", n / 2);
    let d = args.get_usize("channels", 4);
    let f = parse_f(args.get_str("f", "exp"), args.get_f64("lambda", 0.5))?;
    let icfg = integrator_config(args)?;
    let policy = icfg.to_policy()?;
    // The graph/ensemble backends only run the f64 tier; parsing here
    // surfaces `--precision f32` as a typed build error below.
    let precision = icfg.to_precision()?;
    let method = ecfg.to_method()?;
    let mut rng = Pcg::seed(args.get_usize("seed", 0) as u64);
    let g = generators::path_plus_random_edges(n, extra, &mut rng);
    let x = Matrix::randn(n, d, &mut rng);
    println!(
        "graph: path({n}) + {extra} random edges; ensemble {}×{method} (seed {}); f = {f:?}",
        ecfg.trees, ecfg.seed
    );

    let (brute, t_bpre) = time_once(|| BruteForceIntegrator::from_graph(&g));
    let (want, t_brute) = time_once(|| brute.integrate(&f, &x));
    let want = want?;
    println!("brute (graph metric): preprocess {t_bpre:.3}s, integrate {t_brute:.4}s");

    let (mst, t_mpre) = time_once(|| {
        ftfi::GraphFieldIntegrator::builder(&g)
            .leaf_threshold(icfg.leaf_threshold)
            .policy(policy.clone())
            .threads(icfg.threads)
            .precision(precision)
            .build()
    });
    let mst = mst?;
    let (mst_out, t_mint) = time_once(|| mst.try_integrate(&f, &x));
    let rel_mst = mst_out?.frobenius_diff(&want) / (1.0 + want.frobenius());
    println!(
        "single MST:  preprocess {t_mpre:.3}s, integrate {t_mint:.4}s, rel err {rel_mst:.3e}"
    );

    let (ens, t_epre) = time_once(|| {
        EnsembleFieldIntegrator::builder(&g)
            .trees(ecfg.trees)
            .seed(ecfg.seed)
            .method(method)
            .leaf_threshold(icfg.leaf_threshold)
            .policy(policy)
            .threads(icfg.threads)
            .precision(precision)
            .build()
    });
    let ens = ens?;
    let st = ens.stats();
    println!(
        "ensemble:    {} trees sampled in {t_epre:.3}s ({} tree vertices, {} Steiner), \
         {} integration threads",
        st.trees,
        st.tree_vertices_total,
        st.steiner_total,
        ens.pool().threads()
    );
    let (prepared, t_plan) = time_once(|| ens.prepare_with_channels(&f, d));
    let prepared = prepared?;
    let (got, t_eint) = time_once(|| prepared.integrate(&x));
    let rel_ens = got?.frobenius_diff(&want) / (1.0 + want.frobenius());
    println!(
        "ensemble:    prepare {t_plan:.3}s ({} plans), integrate {t_eint:.4}s, \
         rel err {rel_ens:.3e}",
        prepared.plans_built()
    );
    Ok(())
}

/// Resolve the streaming knobs from `--config` (the `[streaming]`
/// section) plus direct CLI overrides.
fn streaming_config(args: &Args) -> Result<StreamingConfig, Box<dyn std::error::Error>> {
    let mut cfg = match args.get("config") {
        Some(path) => StreamingConfig::from_config(&Config::load(path)?),
        None => StreamingConfig::default(),
    };
    if let Some(r) = args.get("refresh-every") {
        cfg.refresh_every = r.parse().map_err(|_| format!("bad --refresh-every {r:?}"))?;
    }
    if let Some(s) = args.get("max-sessions") {
        cfg.max_sessions = s.parse().map_err(|_| format!("bad --max-sessions {s:?}"))?;
    }
    if let Some(p) = args.get("max-pending") {
        cfg.max_pending = p.parse().map_err(|_| format!("bad --max-pending {p:?}"))?;
    }
    if let Some(s) = args.get("shed-after-ms") {
        cfg.shed_after_ms = s.parse().map_err(|_| format!("bad --shed-after-ms {s:?}"))?;
    }
    Ok(cfg)
}

/// Resolve the multi-graph plan-cache knobs from `--config` (the
/// `[cache]` section) plus direct CLI overrides.
fn cache_config(args: &Args) -> Result<CacheConfig, Box<dyn std::error::Error>> {
    let mut cfg = match args.get("config") {
        Some(path) => CacheConfig::from_config(&Config::load(path)?),
        None => CacheConfig::default(),
    };
    if let Some(g) = args.get("cache-graphs") {
        cfg.max_graphs = g.parse().map_err(|_| format!("bad --cache-graphs {g:?}"))?;
    }
    if let Some(b) = args.get("cache-bytes-mb") {
        cfg.max_bytes_mb = b.parse().map_err(|_| format!("bad --cache-bytes-mb {b:?}"))?;
    }
    if let Some(v) = args.get("fuse-updates") {
        cfg.fuse_updates = match v {
            "on" | "true" | "1" => true,
            "off" | "false" | "0" => false,
            other => return Err(format!("bad --fuse-updates {other:?} (on|off)").into()),
        };
    }
    Ok(cfg)
}

/// The sparse-delta route of `integrate`: apply a k-row update to an
/// already-integrated field and compare the delta fast path against a
/// full prepared re-integration — wall clock and superposition drift.
fn cmd_integrate_delta(args: &Args, k: usize) -> CliResult {
    let n = args.get_usize("n", 4000);
    let d = args.get_usize("channels", 4);
    let repeat = args.get_usize("repeat", 16).max(1);
    let k = k.min(n);
    let f = parse_f(args.get_str("f", "invquad"), args.get_f64("lambda", 0.5))?;
    let icfg = integrator_config(args)?;
    let policy = icfg.to_policy()?;
    let mut rng = Pcg::seed(args.get_usize("seed", 0) as u64);
    let g = generators::path_plus_random_edges(n, n / 2, &mut rng);
    let tree = try_minimum_spanning_tree(&g)?;
    let tfi = TreeFieldIntegrator::builder(&tree)
        .leaf_threshold(icfg.leaf_threshold)
        .policy(policy)
        .threads(icfg.threads)
        .precision(icfg.to_precision()?)
        .build()?;
    let plans = tfi.prepare_plans(&f, d)?;
    let x = Matrix::randn(n, d, &mut rng);
    let mut base = Matrix::zeros(n, d);
    tfi.integrate_prepared_into(&x, &plans, &mut base)?;

    // k distinct dirty rows + their delta field.
    let (rows, dx) = ftfi::bench_util::sparse_delta(n, d, k, &mut rng);
    let mut x2 = x.clone();
    x2.axpy(1.0, &dx);

    let mut dout = Matrix::zeros(n, d);
    let mut full = Matrix::zeros(n, d);
    let visits_before = tfi.stats().delta_nodes_visited;
    let (_, t_delta) = time_once(|| {
        for _ in 0..repeat {
            tfi.integrate_delta_prepared_into(&rows, &dx, &plans, &mut dout)
                .expect("delta integrate");
        }
    });
    let visits = (tfi.stats().delta_nodes_visited - visits_before) / repeat;
    let (_, t_full) = time_once(|| {
        for _ in 0..repeat {
            tfi.integrate_prepared_into(&x2, &plans, &mut full).expect("full integrate");
        }
    });
    let mut approx = base.clone();
    approx.axpy(1.0, &dout);
    let drift = approx.max_abs_diff(&full);
    println!(
        "delta update: n = {n}, d = {d}, k = {k}, f = {f:?} ({} threads)",
        tfi.pool().threads()
    );
    println!(
        "delta {:.3} ms/update vs full {:.3} ms/recompute ({:.1}x), max abs drift {drift:.2e}, \
         {visits} delta node visits/update",
        t_delta / repeat as f64 * 1e3,
        t_full / repeat as f64 * 1e3,
        t_full / t_delta.max(1e-12)
    );
    Ok(())
}

/// The edge-replan route of `integrate`: reweight `k` tree edges
/// through the in-place separator-walk re-plan and compare against a
/// full rebuild-from-scratch + re-prepare — wall clock, nodes visited
/// per replan, and the rebuild-equivalence drift of the served output.
fn cmd_integrate_replan(args: &Args, k: usize) -> CliResult {
    let n = args.get_usize("n", 4000);
    let d = args.get_usize("channels", 4);
    let repeat = args.get_usize("repeat", 8).max(1);
    let f = parse_f(args.get_str("f", "invquad"), args.get_f64("lambda", 0.5))?;
    let icfg = integrator_config(args)?;
    let policy = icfg.to_policy()?;
    let precision = icfg.to_precision()?;
    let mut rng = Pcg::seed(args.get_usize("seed", 0) as u64);
    let g = generators::path_plus_random_edges(n, n / 2, &mut rng);
    let mut tree = try_minimum_spanning_tree(&g)?;
    let k = k.clamp(1, tree.edges().len());
    let build = |tree: &ftfi::Tree| {
        TreeFieldIntegrator::builder(tree)
            .leaf_threshold(icfg.leaf_threshold)
            .policy(policy.clone())
            .threads(icfg.threads)
            .precision(precision)
            .build()
    };
    let mut tfi = build(&tree)?;
    let mut plans = tfi.prepare_plans(&f, d)?;
    let x = Matrix::randn(n, d, &mut rng);

    // k distinct edges to reweight; timed passes flip each between its
    // original weight and 1.5× (a same-weight replan is a no-op).
    let picks: Vec<(usize, usize, f64)> = rng
        .sample_distinct(tree.edges().len(), k)
        .into_iter()
        .map(|i| {
            let (u, v, w) = tree.edges()[i];
            (u as usize, v as usize, w)
        })
        .collect();

    // Equivalence first: one replan pass must serve the same output as
    // a rebuild-from-scratch on the mutated tree (bit-identical — the
    // separator hierarchy is weight-independent).
    for &(u, v, w) in &picks {
        tfi.replan_edge_prepared(u, v, w * 1.5, &mut plans)?;
        tree.set_edge_weight(u, v, w * 1.5)
            .ok_or("edge vanished while replanning")?;
    }
    let mut out_replan = Matrix::zeros(n, d);
    tfi.integrate_prepared_into(&x, &plans, &mut out_replan)?;
    let rebuilt = build(&tree)?;
    let rplans = rebuilt.prepare_plans(&f, d)?;
    let mut out_rebuild = Matrix::zeros(n, d);
    rebuilt.integrate_prepared_into(&x, &rplans, &mut out_rebuild)?;
    let drift = out_replan.max_abs_diff(&out_rebuild);

    let visits_before = tfi.stats().replan_nodes_visited;
    let (_, t_replan) = time_once(|| {
        for r in 0..repeat {
            let scale = if r % 2 == 0 { 1.0 } else { 1.5 };
            for &(u, v, w) in &picks {
                tfi.replan_edge_prepared(u, v, w * scale, &mut plans).expect("replan edge");
            }
        }
    });
    let visits = (tfi.stats().replan_nodes_visited - visits_before) / (repeat * k);
    let (_, t_full) = time_once(|| {
        for _ in 0..repeat {
            let t = build(&tree).expect("rebuild integrator");
            t.prepare_plans(&f, d).expect("re-prepare plans");
        }
    });
    println!(
        "edge replan: n = {n}, d = {d}, k = {k}, f = {f:?} ({} threads)",
        tfi.pool().threads()
    );
    println!(
        "replan {:.3} ms/batch vs rebuild+prepare {:.3} ms ({:.1}x), {visits} nodes \
         visited/replan, rebuild-equivalence max abs diff {drift:.2e}",
        t_replan / repeat as f64 * 1e3,
        t_full / repeat as f64 * 1e3,
        t_full / t_replan.max(1e-12)
    );
    Ok(())
}

fn cmd_integrate(args: &Args) -> CliResult {
    let ecfg = ensemble_config(args)?;
    if ecfg.enabled() {
        return cmd_integrate_ensemble(args, &ecfg);
    }
    if let Some(k) = args.get("delta-rows") {
        let k: usize = k.parse().map_err(|_| format!("bad --delta-rows {k:?}"))?;
        return cmd_integrate_delta(args, k);
    }
    if let Some(k) = args.get("replan-edges") {
        let k: usize = k.parse().map_err(|_| format!("bad --replan-edges {k:?}"))?;
        return cmd_integrate_replan(args, k);
    }
    let n = args.get_usize("n", 5000);
    let extra = args.get_usize("extra-edges", n / 2);
    let d = args.get_usize("channels", 4);
    let repeat = args.get_usize("repeat", 1).max(1);
    let f = parse_f(args.get_str("f", "exp"), args.get_f64("lambda", 0.5))?;
    let icfg = integrator_config(args)?;
    let policy = icfg.to_policy()?;
    let mut rng = Pcg::seed(args.get_usize("seed", 0) as u64);

    println!("graph: path({n}) + {extra} random edges; field channels = {d}; f = {f:?}");
    let g = generators::path_plus_random_edges(n, extra, &mut rng);
    let (tree, t_mst) = time_once(|| try_minimum_spanning_tree(&g));
    let tree = tree?;
    let x = Matrix::randn(n, d, &mut rng);

    let (tfi, t_pre) = time_once(|| {
        TreeFieldIntegrator::builder(&tree)
            .leaf_threshold(icfg.leaf_threshold)
            .policy(policy.clone())
            .threads(icfg.threads)
            .precision(icfg.to_precision()?)
            .build()
    });
    let tfi = tfi?;
    println!("integration threads: {}", tfi.pool().threads());
    let (prepared, t_plan) = time_once(|| tfi.prepare_with_channels(&f, d));
    let prepared = prepared?;
    let (fast, t_fast) = time_once(|| prepared.integrate(&x));
    let fast = fast?;
    println!(
        "FTFI:  preprocess {t_pre:.3}s (+ MST {t_mst:.3}s), prepare {t_plan:.3}s \
         ({} plans), integrate {t_fast:.4}s",
        prepared.plans_built()
    );
    if repeat > 1 {
        let (_, t_rep) = time_once(|| {
            for _ in 0..repeat - 1 {
                prepared.integrate(&x).expect("prepared integrate");
            }
        });
        let (_, t_replan) = time_once(|| {
            for _ in 0..repeat - 1 {
                tfi.try_integrate(&f, &x).expect("replanning integrate");
            }
        });
        println!(
            "repeat×{}: prepared {t_rep:.4}s vs re-planning {t_replan:.4}s ({:.1}x)",
            repeat - 1,
            t_replan / t_rep.max(1e-12)
        );
    }

    let (brute, t_bpre) = time_once(|| BruteTreeIntegrator::new(&tree, &f));
    let (slow, t_slow) = time_once(|| brute.integrate(&x));
    println!("BTFI:  preprocess {t_bpre:.3}s, integrate {t_slow:.4}s");
    let rel = fast.frobenius_diff(&slow) / (1.0 + slow.frobenius());
    println!(
        "relative error {rel:.2e}; end-to-end speedup {:.1}x",
        (t_bpre + t_slow) / (t_pre + t_plan + t_fast)
    );
    Ok(())
}

/// Serve FTFI field integrations through the coordinator (default
/// backend). `--backend topvit` switches to the PJRT model path, which
/// needs the `pjrt` feature.
fn cmd_serve(args: &Args) -> CliResult {
    if args.get_flag("streaming") {
        return cmd_serve_streaming(args);
    }
    match args.get_str("backend", "field") {
        "field" => cmd_serve_field(args),
        "ensemble" => cmd_serve_ensemble(args),
        "topvit" => cmd_serve_topvit(args),
        other => Err(format!("unknown backend {other:?} (field|ensemble|topvit)").into()),
    }
}

/// Serve the streaming workload: one shared [`StreamingFieldExecutor`]
/// (session table, tree, frozen plans, work pool — all global to the
/// server) behind an `Arc`, every worker dispatching set/update
/// requests into it. Each simulated client opens a session and then
/// mutates `--delta-rows` rows per tick; `--replan-edges r` follows up
/// with `r` in-place edge re-plans of the shared metric.
///
/// `--wire typed` (the default) speaks the checksummed binary protocol
/// of [`ftfi::coordinator::protocol`] with seeded-backoff retries on
/// backpressure; `--wire legacy` keeps the original float-opcode frames
/// (parsed into the same typed requests at the executor boundary).
fn cmd_serve_streaming(args: &Args) -> CliResult {
    let n = args.get_usize("n", 2000);
    let n_requests = args.get_usize("requests", 200);
    let batch = args.get_usize("batch", 8);
    let workers = args.get_usize("workers", 2);
    let k = args.get_usize("delta-rows", 4).min(n);
    let replans = args.get_usize("replan-edges", 0);
    let wire = args.get_str("wire", "typed");
    let typed = match wire {
        "typed" => true,
        "legacy" => false,
        other => return Err(format!("unknown --wire {other:?} (typed|legacy)").into()),
    };
    let f = parse_f(args.get_str("f", "exp"), args.get_f64("lambda", 0.5))?;
    let icfg = integrator_config(args)?;
    let policy = icfg.to_policy()?;
    let scfg = streaming_config(args)?;
    let ccfg = cache_config(args)?;
    let sessions = args.get_usize("sessions", 4).clamp(1, scfg.max_sessions.max(1));
    let graphs = args.get_usize("graphs", 1).max(1);
    if graphs > 1 && !typed {
        return Err("--graphs > 1 needs --wire typed (OpenGraph has no legacy opcode)".into());
    }

    let mut rng = Pcg::seed(7);
    let g = generators::path_plus_random_edges(n, n / 2, &mut rng);
    let tree = try_minimum_spanning_tree(&g)?;
    let pool = Arc::new(WorkPool::with_auto(icfg.threads));
    let tfi = TreeFieldIntegrator::builder(&tree)
        .leaf_threshold(icfg.leaf_threshold)
        .policy(policy)
        .pool(Arc::clone(&pool))
        .precision(icfg.to_precision()?)
        .build()?;
    // One registry shared by the executor (update latency, evictions,
    // protocol errors) and the server (queue, shed, retries).
    let metrics = Arc::new(MetricsRegistry::new());
    let exec = Arc::new(
        StreamingFieldExecutor::new(
            tfi,
            &f,
            1,
            scfg.refresh_every,
            scfg.max_sessions,
            batch.max(1),
        )?
        .with_cache(ccfg.clone())
        .with_max_pending(scfg.max_pending)
        .with_metrics(Arc::clone(&metrics)),
    );
    println!(
        "streaming serve: f = {f:?}, n = {n}, {sessions} sessions over {graphs} graph(s) \
         on the {wire} wire (plan cache {} graphs, fusion {}, refresh every {}, \
         {workers} workers, {} integration threads shared)",
        ccfg.max_graphs,
        if ccfg.fuse_updates { "on" } else { "off" },
        scfg.refresh_every,
        pool.threads()
    );
    // Graph 0 is the default (built into the executor); graphs 1..G are
    // opened through the plan cache with client-supplied edge lists.
    let extra_graphs: Vec<Vec<(u32, u32, f64)>> = (1..graphs)
        .map(|gi| {
            let mut grng = Pcg::seed(1000 + gi as u64);
            generators::random_tree(n, 0.2, 1.0, &mut grng).edges().to_vec()
        })
        .collect();

    let factories: Vec<Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>> = (0..workers
        .max(1))
        .map(|_| {
            let exec = Arc::clone(&exec);
            Box::new(move || {
                Box::new(exec) as Box<dyn BatchExecutor>
            }) as Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>
        })
        .collect();
    let shed_after = (scfg.shed_after_ms > 0).then(|| Duration::from_millis(scfg.shed_after_ms));
    let server = InferenceServer::start_with_metrics(
        factories,
        BatcherConfig {
            batch_size: batch.max(1),
            batch_timeout: Duration::from_millis(2),
            shed_after,
        },
        1024,
        Arc::clone(&metrics),
    );

    // Non-blocking submit under seeded exponential backoff: the bounded
    // queue's Backpressure is the one retryable submit error.
    let submit = |req: Vec<f32>, seed: u64| {
        let (res, retries) = retry_with_backoff(&BackoffPolicy::default(), seed, |_| {
            match server.submit(req.clone()) {
                Ok(h) => RetryStep::Done(h),
                Err(ServerError::Backpressure) => RetryStep::Retry(ServerError::Backpressure),
                Err(e) => RetryStep::Fail(e),
            }
        });
        if retries > 0 {
            metrics.record_retries(u64::from(retries));
        }
        res.map_err(|e| e.to_string())
    };
    // Classify a response as (served, rejected-by-admission). On the
    // legacy wire rejections surface as plain exec errors.
    let classify = |res: Result<Vec<f32>, ServerError>| match res {
        Ok(words) if typed => match protocol::response_from_words(&words) {
            Ok((_, StreamResponse::Rejected { .. })) => (false, true),
            Ok((_, StreamResponse::Error { .. })) | Err(_) => (false, false),
            Ok(_) => (true, false),
        },
        Ok(_) => (true, false),
        Err(_) => (false, false),
    };

    // Open every session (OpenGraph for sessions bound to a non-default
    // graph, then a full-field set), then stream updates.
    for s in 0..sessions {
        let gi = s % graphs;
        if gi > 0 {
            let edges = &extra_graphs[gi - 1];
            let req = protocol::request_words(
                &StreamRequest::OpenGraph {
                    session: s as u32,
                    n: n as u32,
                    edges: edges.clone(),
                },
                50_000 + s as u64,
            );
            if !classify(submit(req, 50_000 + s as u64)?.wait()).0 {
                return Err(format!("session {s} failed to open graph {gi}").into());
            }
        }
        let values: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let req = if typed {
            protocol::request_words(
                &StreamRequest::Set { session: s as u32, rows: n as u32, channels: 1, values },
                s as u64,
            )
        } else {
            let mut req = vec![0.0f32, s as f32];
            req.extend(values);
            req
        };
        if !classify(submit(req, s as u64)?.wait()).0 {
            return Err(format!("session {s} failed to open").into());
        }
    }
    println!("submitting {n_requests} updates of k = {k} rows (batch {batch})...");
    let mut handles = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        // Rows i·k.. wrap around the vertex set: distinct within one
        // update, drifting across updates.
        let rows: Vec<u32> = (0..k).map(|j| ((i * k + j) % n) as u32).collect();
        let values: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
        let req = if typed {
            protocol::request_words(
                &StreamRequest::Update {
                    session: (i % sessions) as u32,
                    rows,
                    channels: 1,
                    values,
                },
                100 + i as u64,
            )
        } else {
            let mut req = vec![1.0f32, (i % sessions) as f32, k as f32];
            req.extend(rows.iter().map(|&r| r as f32));
            req.extend(values);
            req
        };
        handles.push(submit(req, 100 + i as u64)?);
    }
    let (mut ok, mut rejected) = (0usize, 0usize);
    for h in handles {
        let (served, shed) = classify(h.wait());
        ok += usize::from(served);
        rejected += usize::from(shed);
    }
    if replans > 0 {
        // Stream in-place edge re-plans over real tree edges;
        // alternating scales keep every replan an actual change.
        println!("submitting {replans} edge replans...");
        let edges = tree.edges().to_vec();
        let mut rhandles = Vec::with_capacity(replans);
        for j in 0..replans {
            let (u, v, w) = edges[j % edges.len()];
            let scale = if (j / edges.len()) % 2 == 0 { 1.5 } else { 1.0 };
            let req = if typed {
                protocol::request_words(
                    &StreamRequest::ReplanEdge {
                        session: (j % sessions) as u32,
                        u,
                        v,
                        w: w * scale,
                    },
                    10_000 + j as u64,
                )
            } else {
                vec![2.0f32, (j % sessions) as f32, u as f32, v as f32, (w * scale) as f32]
            };
            rhandles.push(submit(req, 10_000 + j as u64)?);
        }
        let mut replan_ok = 0;
        for h in rhandles {
            if classify(h.wait()).0 {
                replan_ok += 1;
            }
        }
        println!("replans acknowledged: {replan_ok}/{replans}");
    }
    let m = server.metrics();
    println!(
        "served {ok}/{n_requests} ({rejected} rejected by admission): {:.0} req/s, \
         request p50 {:.1}ms p95 {:.1}ms; update p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms \
         ({} updates recorded)",
        m.throughput_rps,
        m.latency_p50 * 1e3,
        m.latency_p95 * 1e3,
        m.update_p50 * 1e3,
        m.update_p95 * 1e3,
        m.update_p99 * 1e3,
        m.updates
    );
    println!(
        "robustness counters: {} protocol errors, {} evictions, {} shed, {} retries",
        m.protocol_errors, m.sessions_evicted, m.requests_shed, m.retries
    );
    println!(
        "plan cache: {} hits / {} misses / {} evictions ({} resident graphs); \
         fusion: {} updates fused, {} delta rows saved",
        m.cache_hits,
        m.cache_misses,
        m.cache_evictions,
        m.cache_graphs,
        m.fused_updates,
        m.fusion_rows_saved
    );
    server.shutdown();
    Ok(())
}

/// Serve the tree-ensemble backend: one shared [`EnsembleFieldIntegrator`]
/// (sampling + preprocessing paid once) behind an `Arc`, every worker
/// running a [`FieldExecutor`] over it — all on one shared work pool.
fn cmd_serve_ensemble(args: &Args) -> CliResult {
    let n = args.get_usize("n", 1000);
    let n_requests = args.get_usize("requests", 200);
    let batch = args.get_usize("batch", 8);
    let workers = args.get_usize("workers", 2);
    let f = parse_f(args.get_str("f", "exp"), args.get_f64("lambda", 0.5))?;
    let icfg = integrator_config(args)?;
    let policy = icfg.to_policy()?;
    let mut ecfg = ensemble_config(args)?;
    if !ecfg.enabled() {
        // `--backend ensemble` implies an ensemble even without the flag.
        ecfg.trees = 4;
    }
    let method = ecfg.to_method()?;

    let mut rng = Pcg::seed(7);
    let g = generators::path_plus_random_edges(n, n / 2, &mut rng);
    let pool = Arc::new(WorkPool::with_auto(icfg.threads));
    let ens = Arc::new(
        EnsembleFieldIntegrator::builder(&g)
            .trees(ecfg.trees)
            .seed(ecfg.seed)
            .method(method)
            .leaf_threshold(icfg.leaf_threshold)
            .policy(policy)
            .pool(Arc::clone(&pool))
            .precision(icfg.to_precision()?)
            .build()?,
    );
    println!(
        "serving f = {f:?} over an n = {n} {}×{method} ensemble metric ({workers} workers, \
         {} integration threads shared)",
        ens.trees(),
        pool.threads()
    );

    let factories: Vec<Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>> = (0..workers
        .max(1))
        .map(|_| {
            let ens = Arc::clone(&ens);
            let f = f.clone();
            Box::new(move || {
                Box::new(FieldExecutor::new(ens, f, 8)) as Box<dyn BatchExecutor>
            }) as Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>
        })
        .collect();
    let server = InferenceServer::start(
        factories,
        BatcherConfig {
            batch_size: batch.max(1),
            batch_timeout: Duration::from_millis(2),
            shed_after: None,
        },
        1024,
    );
    println!("submitting {n_requests} requests (batch {batch})...");
    let fields: Vec<Vec<f32>> = (0..8)
        .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
        .collect();
    let handles: Vec<_> = (0..n_requests)
        .map(|i| server.submit_blocking(fields[i % fields.len()].clone()).unwrap())
        .collect();
    let mut ok = 0;
    for h in handles {
        if h.wait().is_ok() {
            ok += 1;
        }
    }
    let m = server.metrics();
    println!(
        "served {ok}/{n_requests}: {:.0} req/s, mean batch {:.2}, p50 {:.1}ms p95 {:.1}ms p99 {:.1}ms",
        m.throughput_rps,
        m.mean_batch_size,
        m.latency_p50 * 1e3,
        m.latency_p95 * 1e3,
        m.latency_p99 * 1e3
    );
    server.shutdown();
    Ok(())
}

fn cmd_serve_field(args: &Args) -> CliResult {
    let n = args.get_usize("n", 2000);
    let n_requests = args.get_usize("requests", 200);
    let batch = args.get_usize("batch", 8);
    let workers = args.get_usize("workers", 2);
    let f = parse_f(args.get_str("f", "exp"), args.get_f64("lambda", 0.5))?;
    let icfg = integrator_config(args)?;
    let policy = icfg.to_policy()?;
    let precision = icfg.to_precision()?;

    let mut rng = Pcg::seed(7);
    let g = generators::path_plus_random_edges(n, n / 2, &mut rng);
    let tree = try_minimum_spanning_tree(&g)?;
    // One shared pool across all workers: the process-wide integration
    // thread budget stays bounded no matter how many workers race.
    let pool = Arc::new(WorkPool::with_auto(icfg.threads));
    println!(
        "serving f = {f:?} over an n = {n} MST metric ({workers} workers, {} integration \
         threads shared)",
        pool.threads()
    );

    let factories: Vec<Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>> = (0..workers
        .max(1))
        .map(|_| {
            let tree = tree.clone();
            let f = f.clone();
            let policy = policy.clone();
            let leaf_threshold = icfg.leaf_threshold;
            let pool = Arc::clone(&pool);
            Box::new(move || {
                let tfi = TreeFieldIntegrator::builder(&tree)
                    .leaf_threshold(leaf_threshold)
                    .policy(policy)
                    .pool(pool)
                    .precision(precision)
                    .build()
                    .expect("validated tree");
                Box::new(
                    PreparedFieldExecutor::new(tfi, &f, 1, 8).expect("validated policy"),
                ) as Box<dyn BatchExecutor>
            }) as Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>
        })
        .collect();
    let server = InferenceServer::start(
        factories,
        BatcherConfig {
            batch_size: batch.max(1),
            batch_timeout: Duration::from_millis(2),
            shed_after: None,
        },
        1024,
    );
    println!("submitting {n_requests} requests (batch {batch})...");
    let fields: Vec<Vec<f32>> = (0..8)
        .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
        .collect();
    let handles: Vec<_> = (0..n_requests)
        .map(|i| server.submit_blocking(fields[i % fields.len()].clone()).unwrap())
        .collect();
    let mut ok = 0;
    for h in handles {
        if h.wait().is_ok() {
            ok += 1;
        }
    }
    let m = server.metrics();
    println!(
        "served {ok}/{n_requests}: {:.0} req/s, mean batch {:.2}, p50 {:.1}ms p95 {:.1}ms p99 {:.1}ms",
        m.throughput_rps,
        m.mean_batch_size,
        m.latency_p50 * 1e3,
        m.latency_p95 * 1e3,
        m.latency_p99 * 1e3
    );
    server.shutdown();
    Ok(())
}

fn cmd_gw(args: &Args) -> CliResult {
    let n = args.get_usize("n", 300);
    let mut rng = Pcg::seed(5);
    let ta = generators::random_tree(n, 0.1, 1.0, &mut rng);
    let tb = generators::random_tree(n, 0.1, 1.0, &mut rng);
    let p = uniform_marginal(n);
    for (name, backend) in [("dense", GwBackend::Dense), ("ftfi", GwBackend::Ftfi)] {
        let (r, total) =
            time_once(|| gromov_wasserstein(&ta, &tb, &p, &p, backend, &GwParams::default()));
        let r = r?;
        println!(
            "{name:>5}: GW {:.5} in {total:.2}s total, {:.2}s field integration ({} CG iters)",
            r.discrepancy, r.integration_seconds, r.iterations
        );
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &Args) -> CliResult {
    use ftfi::ml::shapes;
    use ftfi::runtime::topvit::{TopVit, TRAIN_BATCH};
    use ftfi::runtime::Runtime;
    let steps = args.get_usize("steps", 200);
    let lr = args.get_f64("lr", 0.01) as f32;
    let masked = !args.get_flag("unmasked");
    let params_bin =
        if masked { "topvit_init_masked.bin" } else { "topvit_init_unmasked.bin" };
    let rt = Runtime::cpu()?;
    let mut model = TopVit::load(&rt, "artifacts", params_bin, &[], true)?;
    let mut rng = Pcg::seed(1);
    let data = shapes::dataset(64, &mut rng);
    println!(
        "training TopViT-mini ({}) for {steps} steps, lr {lr}",
        if masked { "masked" } else { "unmasked" }
    );
    for step in 0..steps {
        let (images, labels) = shapes::pack_batch(&data, step * TRAIN_BATCH, TRAIN_BATCH);
        let loss = model.train_step(&images, &labels, lr)?;
        if step % 20 == 0 || step + 1 == steps {
            println!("step {step:>4}  loss {loss:.4}");
        }
    }
    println!("final mask parameters: {:?}", model.mask_params());
    if let Some(out) = args.get("save") {
        model.params.save_bin(out)?;
        println!("saved parameters to {out}");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &Args) -> CliResult {
    Err("the `train` command needs the PJRT runtime — rebuild with `--features pjrt`".into())
}

#[cfg(feature = "pjrt")]
fn cmd_serve_topvit(args: &Args) -> CliResult {
    use ftfi::ml::shapes;
    use ftfi::runtime::topvit::{TopVit, TopVitExecutor};
    use ftfi::runtime::Runtime;
    let n_requests = args.get_usize("requests", 200);
    let batch = args.get_usize("batch", 8);
    let server = InferenceServer::start(
        vec![Box::new(move || {
            let rt = Runtime::cpu().expect("PJRT client");
            let model = TopVit::load(&rt, "artifacts", "topvit_init_masked.bin", &[8], false)
                .expect("load TopViT");
            Box::new(TopVitExecutor::new(model, 8)) as Box<dyn BatchExecutor>
        })],
        BatcherConfig {
            batch_size: batch.min(8),
            batch_timeout: Duration::from_millis(2),
            shed_after: None,
        },
        1024,
    );
    let mut rng = Pcg::seed(3);
    let data = shapes::dataset(8, &mut rng);
    println!("submitting {n_requests} requests (batch {batch})...");
    let handles: Vec<_> = (0..n_requests)
        .map(|i| server.submit_blocking(data[i % data.len()].pixels.clone()).unwrap())
        .collect();
    let mut ok = 0;
    for h in handles {
        if h.wait().is_ok() {
            ok += 1;
        }
    }
    let m = server.metrics();
    println!(
        "served {ok}/{n_requests}: {:.0} req/s, mean batch {:.2}, p50 {:.1}ms p95 {:.1}ms p99 {:.1}ms",
        m.throughput_rps,
        m.mean_batch_size,
        m.latency_p50 * 1e3,
        m.latency_p95 * 1e3,
        m.latency_p99 * 1e3
    );
    server.shutdown();
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve_topvit(_args: &Args) -> CliResult {
    Err("the TopViT backend needs the PJRT runtime — rebuild with `--features pjrt`".into())
}

fn cmd_info() -> CliResult {
    println!("ftfi {} — Fast Tree-Field Integrators", env!("CARGO_PKG_VERSION"));
    #[cfg(feature = "pjrt")]
    {
        use ftfi::runtime::Runtime;
        match Runtime::cpu() {
            Ok(rt) => println!("PJRT platform: {}", rt.platform()),
            Err(e) => println!("PJRT unavailable: {e:#}"),
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("PJRT runtime: disabled (build with `--features pjrt`)");
    for name in [
        "sanity_matmul.hlo.txt",
        "topvit_fwd_b1.hlo.txt",
        "topvit_fwd_b8.hlo.txt",
        "topvit_train_b32.hlo.txt",
        "topvit_init_masked.bin",
    ] {
        let path = std::path::Path::new("artifacts").join(name);
        println!(
            "artifact {name:<28} {}",
            if path.exists() { "present" } else { "MISSING (run `make artifacts`)" }
        );
    }
    Ok(())
}
