//! L3 serving coordinator: request router, dynamic batcher and worker
//! pool driving AOT-compiled model executables (the Topological-ViT
//! serving path of §4.4).
//!
//! Architecture (vLLM-router-like, scaled to this repo):
//!
//! ```text
//! clients ──submit──▶ bounded queue ──collector──▶ batches ──▶ workers
//!                      (backpressure)   (size / timeout)        (PJRT)
//! ```
//!
//! Everything is std::thread + channels (no tokio offline); the executor
//! is a trait so unit tests run against a mock while the examples plug in
//! the PJRT-backed [`crate::runtime::Executable`].

//! Robustness layer (PR 9): requests ride a typed, checksummed wire
//! ([`protocol`]) with admission control (leased sessions, LRU
//! eviction, deadline shedding) in front of the executors, and a
//! seeded fault injector ([`faults`]) plus a TCP front-end
//! ([`server::TcpFront`]) prove the exactly-one-response invariant
//! under fire — see DESIGN.md "Serving robustness".

pub mod batcher;
pub mod faults;
pub mod field;
pub mod metrics;
pub mod protocol;
pub mod server;

pub use batcher::{BatchExecutor, Batcher, BatcherConfig};
pub use faults::{FaultCounters, FaultPlan, Faults, FaultyExecutor};
pub use field::{FieldExecutor, PlanCache, PreparedFieldExecutor, StreamingFieldExecutor};
pub use metrics::MetricsRegistry;
pub use protocol::{
    retry_with_backoff, BackoffPolicy, ProtocolError, RejectReason, RetryStep, StreamRequest,
    StreamResponse,
};
pub use server::{InferenceServer, ServerError, TcpFront};
