//! L3 serving coordinator: request router, dynamic batcher and worker
//! pool driving AOT-compiled model executables (the Topological-ViT
//! serving path of §4.4).
//!
//! Architecture (vLLM-router-like, scaled to this repo):
//!
//! ```text
//! clients ──submit──▶ bounded queue ──collector──▶ batches ──▶ workers
//!                      (backpressure)   (size / timeout)        (PJRT)
//! ```
//!
//! Everything is std::thread + channels (no tokio offline); the executor
//! is a trait so unit tests run against a mock while the examples plug in
//! the PJRT-backed [`crate::runtime::Executable`].

pub mod batcher;
pub mod field;
pub mod metrics;
pub mod server;

pub use batcher::{BatchExecutor, Batcher, BatcherConfig};
pub use field::{FieldExecutor, PreparedFieldExecutor, StreamingFieldExecutor};
pub use metrics::MetricsRegistry;
pub use server::{InferenceServer, ServerError};
