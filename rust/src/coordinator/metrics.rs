//! Serving metrics: request/batch counters and latency percentiles.
//!
//! Latencies are recorded into a fixed log-scale histogram (1µs–100s) so
//! snapshots are cheap and lock contention stays negligible on the
//! serving hot path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

// 20 buckets per decade over the 8 decades 1e-6..1e2 (the `* 20.0` in
// `bucket_of` / `/ 20.0` in `bucket_upper`), i.e. ~12% resolution: one
// bucket spans a factor of 10^(1/20) ≈ 1.122.
const BUCKETS: usize = 160;

fn bucket_of(secs: f64) -> usize {
    let clamped = secs.clamp(1e-6, 99.0);
    let log = (clamped / 1e-6).log10(); // 0..8
    ((log * 20.0) as usize).min(BUCKETS - 1)
}

fn bucket_upper(idx: usize) -> f64 {
    1e-6 * 10f64.powf((idx + 1) as f64 / 20.0)
}

/// Shared metrics registry.
pub struct MetricsRegistry {
    requests: AtomicU64,
    batches: AtomicU64,
    batch_items: AtomicU64,
    exec_seconds_micro: AtomicU64,
    latency_hist: Mutex<[u64; BUCKETS]>,
    /// Streaming field updates (the `apply_update` serving path) get
    /// their own histogram: update latency is the SLO of the streaming
    /// workload and must not be averaged into full-integration requests.
    updates: AtomicU64,
    update_hist: Mutex<[u64; BUCKETS]>,
    /// Robustness counters (PR 9): typed decode failures, admission
    /// evictions/sheds, client retries and caught worker panics — the
    /// fault-tolerance surface of the serving stack.
    protocol_errors: AtomicU64,
    sessions_evicted: AtomicU64,
    requests_shed: AtomicU64,
    retries: AtomicU64,
    worker_panics: AtomicU64,
    /// Gauge: requests accepted into the bounded queue and not yet
    /// dispatched (incremented on submit, decremented per response).
    queue_depth: AtomicU64,
    /// Plan-cache counters (PR 10): graph resolutions served from /
    /// missed by the prepared-plan LRU, entries dropped under capacity
    /// or byte pressure, and gauges of the current cache footprint.
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    cache_evictions: AtomicU64,
    cache_graphs: AtomicU64,
    cache_bytes: AtomicU64,
    /// Delta-fusion counters (PR 10): logical updates absorbed into
    /// fused delta passes and the dirty-row applications those passes
    /// saved versus serving each update individually.
    fused_updates: AtomicU64,
    fusion_rows_saved: AtomicU64,
    started: std::time::Instant,
}

/// Point-in-time view.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub exec_seconds_total: f64,
    pub throughput_rps: f64,
    pub latency_p50: f64,
    pub latency_p95: f64,
    pub latency_p99: f64,
    /// Streaming updates recorded (see
    /// [`MetricsRegistry::record_update_latency`]).
    pub updates: u64,
    /// Streaming update-latency percentiles (0.0 until an update is
    /// recorded).
    pub update_p50: f64,
    pub update_p95: f64,
    pub update_p99: f64,
    /// Typed wire frames that failed to decode (checksum, version,
    /// truncation, unknown kind).
    pub protocol_errors: u64,
    /// Session leases evicted under `max_sessions` pressure.
    pub sessions_evicted: u64,
    /// Requests shed by the deadline-based load-shedding policy.
    pub requests_shed: u64,
    /// Client-side retries reported through `record_retries`.
    pub retries: u64,
    /// Worker panics caught by the batcher and fanned out as errors.
    pub worker_panics: u64,
    /// Gauge: accepted-but-undispatched requests right now.
    pub queue_depth: u64,
    /// Graph resolutions served from the prepared-plan cache.
    pub cache_hits: u64,
    /// Graph resolutions that had to build + prepare a new entry.
    pub cache_misses: u64,
    /// Cache entries dropped under capacity / byte-budget pressure.
    pub cache_evictions: u64,
    /// Gauge: graphs currently resident in the plan cache.
    pub cache_graphs: u64,
    /// Gauge: estimated bytes currently held by the plan cache.
    pub cache_bytes: u64,
    /// Logical updates that were absorbed into fused delta passes.
    pub fused_updates: u64,
    /// Dirty-row applications saved by fusing versus one-pass-per-update.
    pub fusion_rows_saved: u64,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_items: AtomicU64::new(0),
            exec_seconds_micro: AtomicU64::new(0),
            latency_hist: Mutex::new([0; BUCKETS]),
            updates: AtomicU64::new(0),
            update_hist: Mutex::new([0; BUCKETS]),
            protocol_errors: AtomicU64::new(0),
            sessions_evicted: AtomicU64::new(0),
            requests_shed: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            worker_panics: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            cache_evictions: AtomicU64::new(0),
            cache_graphs: AtomicU64::new(0),
            cache_bytes: AtomicU64::new(0),
            fused_updates: AtomicU64::new(0),
            fusion_rows_saved: AtomicU64::new(0),
            started: std::time::Instant::now(),
        }
    }

    /// One graph resolution was served by a cached prepared entry.
    pub fn record_cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// One graph resolution missed and built + prepared a new entry.
    pub fn record_cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` cache entries were evicted under capacity / byte pressure.
    pub fn record_cache_evictions(&self, n: u64) {
        self.cache_evictions.fetch_add(n, Ordering::Relaxed);
    }

    /// Refresh the cache-footprint gauges after a resolution.
    pub fn set_cache_usage(&self, graphs: u64, bytes: u64) {
        self.cache_graphs.store(graphs, Ordering::Relaxed);
        self.cache_bytes.store(bytes, Ordering::Relaxed);
    }

    /// A fused delta pass absorbed `updates` logical updates and saved
    /// `rows_saved` dirty-row applications over serving them one by one.
    pub fn record_fusion(&self, updates: u64, rows_saved: u64) {
        self.fused_updates.fetch_add(updates, Ordering::Relaxed);
        self.fusion_rows_saved.fetch_add(rows_saved, Ordering::Relaxed);
    }

    /// One typed wire frame failed to decode.
    pub fn record_protocol_error(&self) {
        self.protocol_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One session lease was evicted under `max_sessions` pressure.
    pub fn record_eviction(&self) {
        self.sessions_evicted.fetch_add(1, Ordering::Relaxed);
    }

    /// One request was shed past its deadline.
    pub fn record_shed(&self) {
        self.requests_shed.fetch_add(1, Ordering::Relaxed);
    }

    /// A client performed `n` retries for one logical request.
    pub fn record_retries(&self, n: u64) {
        self.retries.fetch_add(n, Ordering::Relaxed);
    }

    /// The batcher caught one worker panic.
    pub fn record_worker_panic(&self) {
        self.worker_panics.fetch_add(1, Ordering::Relaxed);
    }

    /// A request entered the bounded submit queue.
    pub fn queue_enter(&self) {
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    /// A request left the queue (response sent or shed). Saturating:
    /// dispatch paths that bypass `queue_enter` (direct batcher unit
    /// tests) must not wrap the gauge.
    pub fn queue_exit(&self) {
        let _ = self
            .queue_depth
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
    }

    pub fn record_batch(&self, items: usize, exec_secs: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_items.fetch_add(items as u64, Ordering::Relaxed);
        self.exec_seconds_micro
            .fetch_add((exec_secs * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn record_latency(&self, secs: f64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        // Poison recovery: the histogram is a plain counter array that
        // stays valid even if a recording thread panicked elsewhere, so
        // metrics keep flowing instead of cascading the panic.
        let mut hist = self.latency_hist.lock().unwrap_or_else(|e| e.into_inner());
        hist[bucket_of(secs)] += 1;
    }

    /// Record one streaming field-update latency (the per-session
    /// `apply_update` wall clock of the streaming executor).
    pub fn record_update_latency(&self, secs: f64) {
        self.updates.fetch_add(1, Ordering::Relaxed);
        let mut hist = self.update_hist.lock().unwrap_or_else(|e| e.into_inner());
        hist[bucket_of(secs)] += 1;
    }

    fn percentile(hist: &[u64; BUCKETS], total: u64, p: f64) -> f64 {
        if total == 0 {
            return 0.0;
        }
        let target = (total as f64 * p).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in hist.iter().enumerate() {
            acc += c;
            if acc >= target {
                return bucket_upper(i);
            }
        }
        bucket_upper(BUCKETS - 1)
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let requests = self.requests.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let items = self.batch_items.load(Ordering::Relaxed);
        let hist = self.latency_hist.lock().unwrap_or_else(|e| e.into_inner());
        let updates = self.updates.load(Ordering::Relaxed);
        let uhist = self.update_hist.lock().unwrap_or_else(|e| e.into_inner());
        let elapsed = self.started.elapsed().as_secs_f64().max(1e-9);
        MetricsSnapshot {
            requests,
            batches,
            mean_batch_size: if batches == 0 { 0.0 } else { items as f64 / batches as f64 },
            exec_seconds_total: self.exec_seconds_micro.load(Ordering::Relaxed) as f64 / 1e6,
            throughput_rps: requests as f64 / elapsed,
            latency_p50: Self::percentile(&hist, requests, 0.50),
            latency_p95: Self::percentile(&hist, requests, 0.95),
            latency_p99: Self::percentile(&hist, requests, 0.99),
            updates,
            update_p50: Self::percentile(&uhist, updates, 0.50),
            update_p95: Self::percentile(&uhist, updates, 0.95),
            update_p99: Self::percentile(&uhist, updates, 0.99),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            sessions_evicted: self.sessions_evicted.load(Ordering::Relaxed),
            requests_shed: self.requests_shed.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            worker_panics: self.worker_panics.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            cache_evictions: self.cache_evictions.load(Ordering::Relaxed),
            cache_graphs: self.cache_graphs.load(Ordering::Relaxed),
            cache_bytes: self.cache_bytes.load(Ordering::Relaxed),
            fused_updates: self.fused_updates.load(Ordering::Relaxed),
            fusion_rows_saved: self.fusion_rows_saved.load(Ordering::Relaxed),
        }
    }
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = MetricsRegistry::new();
        m.record_batch(4, 0.010);
        m.record_batch(2, 0.005);
        for _ in 0..6 {
            m.record_latency(0.002);
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 6);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size - 3.0).abs() < 1e-12);
        assert!((s.exec_seconds_total - 0.015).abs() < 1e-5);
    }

    #[test]
    fn percentiles_ordered_and_bracketing() {
        let m = MetricsRegistry::new();
        // 90 fast + 10 slow.
        for _ in 0..90 {
            m.record_latency(0.001);
        }
        for _ in 0..10 {
            m.record_latency(0.1);
        }
        let s = m.snapshot();
        assert!(s.latency_p50 <= s.latency_p95);
        assert!(s.latency_p95 <= s.latency_p99);
        assert!(s.latency_p50 < 0.01, "p50={}", s.latency_p50);
        assert!(s.latency_p99 > 0.05, "p99={}", s.latency_p99);
    }

    /// Streaming update latencies live in their own histogram: they
    /// must not leak into the request percentiles (and vice versa), and
    /// an empty update histogram reports zeros.
    #[test]
    fn update_latency_percentiles_are_isolated() {
        let m = MetricsRegistry::new();
        let empty = m.snapshot();
        assert_eq!(empty.updates, 0);
        assert_eq!(empty.update_p50, 0.0);
        for _ in 0..90 {
            m.record_update_latency(0.0005);
        }
        for _ in 0..10 {
            m.record_update_latency(0.2);
        }
        m.record_latency(10.0); // a slow full request must not pollute updates
        let s = m.snapshot();
        assert_eq!(s.updates, 100);
        assert_eq!(s.requests, 1);
        assert!(s.update_p50 <= s.update_p95 && s.update_p95 <= s.update_p99);
        assert!(s.update_p50 < 0.005, "p50={}", s.update_p50);
        assert!(s.update_p99 > 0.1, "p99={}", s.update_p99);
        assert!(s.latency_p50 > 5.0, "request percentile must stay separate");
    }

    /// Robustness counters are independent of each other and of the
    /// latency paths (PR 5 isolation style): bumping one must not move
    /// any other, and the queue gauge is saturating, never wrapping.
    #[test]
    fn robustness_counters_are_isolated() {
        let m = MetricsRegistry::new();
        let zero = m.snapshot();
        assert_eq!(
            (zero.protocol_errors, zero.sessions_evicted, zero.requests_shed),
            (0, 0, 0)
        );
        assert_eq!((zero.retries, zero.worker_panics, zero.queue_depth), (0, 0, 0));
        m.record_protocol_error();
        m.record_protocol_error();
        m.record_eviction();
        m.record_shed();
        m.record_retries(5);
        m.record_worker_panic();
        m.queue_enter();
        m.queue_enter();
        m.queue_exit();
        let s = m.snapshot();
        assert_eq!(s.protocol_errors, 2);
        assert_eq!(s.sessions_evicted, 1);
        assert_eq!(s.requests_shed, 1);
        assert_eq!(s.retries, 5);
        assert_eq!(s.worker_panics, 1);
        assert_eq!(s.queue_depth, 1);
        // None of the above may leak into the request/update paths.
        assert_eq!(s.requests, 0);
        assert_eq!(s.updates, 0);
        assert_eq!(s.latency_p50, 0.0);
        assert_eq!(s.update_p50, 0.0);
        // The gauge saturates at zero instead of wrapping.
        m.queue_exit();
        m.queue_exit();
        m.queue_exit();
        assert_eq!(m.snapshot().queue_depth, 0);
        // And latency recording leaves the robustness counters alone.
        m.record_latency(0.001);
        m.record_update_latency(0.001);
        let s2 = m.snapshot();
        assert_eq!(s2.protocol_errors, 2);
        assert_eq!(s2.requests_shed, 1);
        assert_eq!(s2.requests, 1);
        assert_eq!(s2.updates, 1);
    }

    /// Cache and fusion counters are independent of each other, of the
    /// robustness counters and of the latency paths; the footprint
    /// gauges overwrite instead of accumulating.
    #[test]
    fn cache_and_fusion_counters_are_isolated() {
        let m = MetricsRegistry::new();
        let zero = m.snapshot();
        assert_eq!((zero.cache_hits, zero.cache_misses, zero.cache_evictions), (0, 0, 0));
        assert_eq!((zero.cache_graphs, zero.cache_bytes), (0, 0));
        assert_eq!((zero.fused_updates, zero.fusion_rows_saved), (0, 0));
        m.record_cache_hit();
        m.record_cache_hit();
        m.record_cache_miss();
        m.record_cache_evictions(3);
        m.set_cache_usage(4, 1024);
        m.set_cache_usage(2, 512); // gauges overwrite
        m.record_fusion(5, 17);
        m.record_fusion(2, 0);
        let s = m.snapshot();
        assert_eq!(s.cache_hits, 2);
        assert_eq!(s.cache_misses, 1);
        assert_eq!(s.cache_evictions, 3);
        assert_eq!(s.cache_graphs, 2);
        assert_eq!(s.cache_bytes, 512);
        assert_eq!(s.fused_updates, 7);
        assert_eq!(s.fusion_rows_saved, 17);
        // Nothing leaks into the request/update/robustness counters.
        assert_eq!(s.requests, 0);
        assert_eq!(s.updates, 0);
        assert_eq!((s.sessions_evicted, s.requests_shed, s.protocol_errors), (0, 0, 0));
        // And the robustness paths leave the cache counters alone.
        m.record_shed();
        m.record_eviction();
        let s2 = m.snapshot();
        assert_eq!(s2.cache_hits, 2);
        assert_eq!(s2.cache_evictions, 3);
        assert_eq!(s2.sessions_evicted, 1);
    }

    #[test]
    fn bucket_monotone() {
        let mut last = 0;
        for &s in &[1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 0.1, 1.0, 10.0] {
            let b = bucket_of(s);
            assert!(b >= last);
            last = b;
        }
    }

    /// `bucket_of` / `bucket_upper` round-trip: the geometric midpoint of
    /// every bucket maps back to that bucket, and each bucket's upper
    /// edge sits one resolution step (10^(1/20)) above the previous one.
    #[test]
    fn bucket_of_and_bucket_upper_round_trip() {
        let step = 10f64.powf(1.0 / 20.0);
        for idx in 0..BUCKETS {
            let mid = 1e-6 * 10f64.powf((idx as f64 + 0.5) / 20.0);
            if mid < 99.0 {
                assert_eq!(bucket_of(mid), idx, "midpoint {mid} must map to bucket {idx}");
            }
            assert!(bucket_upper(idx) > mid, "upper edge must bound the midpoint");
            if idx > 0 {
                let ratio = bucket_upper(idx) / bucket_upper(idx - 1);
                assert!(
                    (ratio - step).abs() < 1e-9,
                    "bucket {idx}: edge ratio {ratio} != 10^(1/20)"
                );
            }
        }
        // 20 buckets per decade: 1e-6 → bucket 0, 1e-5 → 20, …, 1e-2 → 80.
        assert_eq!(bucket_of(1e-5 * 1.0001), 20);
        assert_eq!(bucket_of(1e-2 * 1.0001), 80);
        // Clamping at both ends.
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(1e-9), 0);
        assert_eq!(bucket_of(1e9), BUCKETS - 1);
    }

    /// Percentiles on a known distribution (1ms, 2ms, …, 100ms): each
    /// reported percentile must land within one bucket width (~12%)
    /// above the exact order statistic.
    #[test]
    fn percentiles_on_known_distribution() {
        let m = MetricsRegistry::new();
        for i in 1..=100 {
            m.record_latency(i as f64 * 1e-3);
        }
        let s = m.snapshot();
        let step = 10f64.powf(1.0 / 20.0);
        let got = [s.latency_p50, s.latency_p95, s.latency_p99];
        let exact = [0.050, 0.095, 0.099];
        for (p, e) in got.iter().zip(exact) {
            assert!(
                *p >= e && *p <= e * step * 1.001,
                "percentile {p} outside [{e}, {}]",
                e * step
            );
        }
    }
}
