//! Typed streaming wire protocol: length-prefixed, versioned,
//! checksummed binary frames carrying [`StreamRequest`] /
//! [`StreamResponse`] values — the replacement for the legacy
//! `[op, session, …]` f32 encoding (kept as a deprecation shim behind
//! `--wire legacy`, parsed into the typed enum at the boundary by
//! [`legacy_to_request`]).
//!
//! ## Frame layout
//!
//! Every frame on a byte stream is `[u32 len][payload…]` (little
//! endian). The payload is:
//!
//! ```text
//! offset  size  field
//! 0       1     version        (WIRE_VERSION = 1)
//! 1       1     kind           (request: 0 set, 1 update, 2 replan,
//!                               3 close, 4 lease, 5 open-graph;
//!                               response: 0 output, 1 closed,
//!                               2 rejected, 3 error)
//! 2       2     flags          (reserved, must be 0)
//! 4       4     checksum       (FNV-1a over the payload with this
//!                               field zeroed)
//! 8       8     req_id         (client-chosen, echoed on the response)
//! 16      …     body           (kind-specific, see the codecs below)
//! ```
//!
//! Row indices and session ids are `u32` on this wire — lifting the
//! legacy encoding's 2²⁴ f32-exactness cap on `n`. A malformed payload
//! decodes to a typed [`ProtocolError`], which the serving stack maps
//! to `ServerError::Protocol`: the frame fails alone, never poisoning a
//! session or its batch-mates.
//!
//! ## Queue transport
//!
//! The coordinator's submit queue is `Vec<f32>` end to end. Typed
//! frames ride it losslessly via [`payload_to_words`]: the payload
//! bytes are packed 4-per-word through `f32::from_bits`, preceded by a
//! NaN-boxed magic word ([`WIRE_MAGIC`]) no legacy opcode can collide
//! with (legacy `input[0]` is 0.0/1.0/2.0) and the byte length. No
//! arithmetic ever touches these words, so the bit patterns (including
//! NaN payloads) survive the channel round trip exactly.

use crate::ml::rng::Pcg;
use std::io::{Read, Write};
use std::time::Duration;

/// Protocol version carried by every frame.
pub const WIRE_VERSION: u8 = 1;

/// First word of a typed request/response on the `Vec<f32>` queue: a
/// quiet-NaN bit pattern (exponent all-ones, payload `F7F1`) that no
/// legacy opcode (finite 0.0/1.0/2.0) can produce.
pub const WIRE_MAGIC: u32 = 0x7FC0_F7F1;

/// Ceiling on one frame's payload size (64 MiB): a corrupted or hostile
/// length prefix fails fast instead of asking the allocator for 4 GiB.
pub const MAX_FRAME: usize = 1 << 26;

/// Error-string prefix the executor uses for typed decode failures on
/// the in-process path; [`crate::coordinator::ServerError`] maps it to
/// `ServerError::Protocol`.
pub const ERR_PROTOCOL_PREFIX: &str = "protocol: ";

/// Error-string prefix the batcher uses for deadline-shed requests; the
/// TCP front-end maps it to `Rejected {{ DeadlineExceeded }}`.
pub const ERR_SHED_PREFIX: &str = "shed: ";

/// Payload header bytes before the kind-specific body.
const HEADER: usize = 16;

/// One typed streaming request. `session` ids are client-chosen `u32`
/// keys into the executor's leased session table (not slot indices).
#[derive(Debug, Clone, PartialEq)]
pub enum StreamRequest {
    /// Install (or overwrite) a session's full `rows × channels` field.
    Set { session: u32, rows: u32, channels: u32, values: Vec<f32> },
    /// Sparse row update through the delta fast path. `channels = 0`
    /// means "infer from the session" (the legacy shim's encoding);
    /// a non-zero value must match the session's width.
    Update { session: u32, rows: Vec<u32>, channels: u32, values: Vec<f32> },
    /// Reweight one tree edge of the shared metric in place.
    ReplanEdge { session: u32, u: u32, v: u32, w: f64 },
    /// Release a session's lease (idempotent).
    Close { session: u32 },
    /// Touch a session's lease and return its current output.
    Lease { session: u32 },
    /// Bind a session to a graph given by its weighted edge list (the
    /// multi-graph plan-cache path). The server canonicalises the edges
    /// into a cache key, building and preparing the graph only on a
    /// miss. A later `Set` on the session integrates against this graph;
    /// re-opening a live session onto a same-`n` graph migrates it in
    /// place (bit-exact full refresh on the new metric). Sessions that
    /// never open a graph resolve to the server's default graph — the
    /// pre-cache behavior, which is also all the legacy shim can reach.
    OpenGraph { session: u32, n: u32, edges: Vec<(u32, u32, f64)> },
}

impl StreamRequest {
    /// The session id every request variant addresses.
    pub fn session(&self) -> u32 {
        match self {
            StreamRequest::Set { session, .. }
            | StreamRequest::Update { session, .. }
            | StreamRequest::ReplanEdge { session, .. }
            | StreamRequest::Close { session }
            | StreamRequest::Lease { session }
            | StreamRequest::OpenGraph { session, .. } => *session,
        }
    }
}

/// Why a request was rejected by admission control (all retryable —
/// after the hinted delay, and after a re-`Set` for `Evicted`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The server's bounded submit queue is full.
    Backpressure,
    /// The session's bounded per-session update queue is full.
    SessionBusy,
    /// The session's lease was evicted under `max_sessions` pressure;
    /// re-`Set` to re-admit.
    Evicted,
    /// The request aged past the load-shedding deadline while queued.
    DeadlineExceeded,
}

impl RejectReason {
    fn code(self) -> u8 {
        match self {
            RejectReason::Backpressure => 0,
            RejectReason::SessionBusy => 1,
            RejectReason::Evicted => 2,
            RejectReason::DeadlineExceeded => 3,
        }
    }

    fn from_code(code: u8) -> Result<Self, ProtocolError> {
        match code {
            0 => Ok(RejectReason::Backpressure),
            1 => Ok(RejectReason::SessionBusy),
            2 => Ok(RejectReason::Evicted),
            3 => Ok(RejectReason::DeadlineExceeded),
            other => Err(ProtocolError::Malformed(format!("unknown reject reason {other}"))),
        }
    }
}

/// One typed streaming response.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamResponse {
    /// The session's full `rows × channels` output.
    Output { session: u32, rows: u32, channels: u32, values: Vec<f32> },
    /// The session's lease was released (idempotent acknowledgement).
    Closed { session: u32 },
    /// Admission control turned the request away; retry after the hint
    /// (re-`Set` first when the reason is `Evicted`).
    Rejected { reason: RejectReason, retry_after_hint_ms: u32 },
    /// The request failed (validation, session state, worker death);
    /// not retryable as-is.
    Error { message: String },
}

/// Typed decode failures. Every variant fails the offending frame alone.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtocolError {
    /// The payload ended before the advertised structure did.
    Truncated { needed: usize, got: usize },
    /// The length prefix exceeds [`MAX_FRAME`].
    FrameTooLarge(usize),
    /// Unknown protocol version byte.
    BadVersion(u8),
    /// Checksum mismatch — the frame was corrupted in flight.
    BadChecksum { expected: u32, got: u32 },
    /// Unknown request/response kind byte.
    UnknownKind(u8),
    /// Structurally invalid body (bad counts, non-utf8 message, …).
    Malformed(String),
    /// The underlying byte stream failed mid-frame.
    Io(String),
}

impl std::fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtocolError::Truncated { needed, got } => {
                write!(f, "truncated frame: needed {needed} bytes, got {got}")
            }
            ProtocolError::FrameTooLarge(n) => {
                write!(f, "frame of {n} bytes exceeds the {MAX_FRAME}-byte cap")
            }
            ProtocolError::BadVersion(v) => {
                write!(f, "unsupported wire version {v} (expected {WIRE_VERSION})")
            }
            ProtocolError::BadChecksum { expected, got } => {
                write!(
                    f,
                    "checksum mismatch: frame says {expected:#010x}, body hashes to {got:#010x}"
                )
            }
            ProtocolError::UnknownKind(k) => write!(f, "unknown frame kind {k}"),
            ProtocolError::Malformed(m) => write!(f, "malformed frame: {m}"),
            ProtocolError::Io(m) => write!(f, "stream error: {m}"),
        }
    }
}

impl std::error::Error for ProtocolError {}

// ---------------------------------------------------------------------
// Checksums and primitive codecs
// ---------------------------------------------------------------------

/// FNV-1a (32-bit) over a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Payload checksum: FNV-1a over the whole payload with the checksum
/// field (bytes 4..8) treated as zero.
fn payload_checksum(payload: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for (i, &b) in payload.iter().enumerate() {
        let b = if (4..8).contains(&i) { 0 } else { b };
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, at: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtocolError> {
        let end = self.at.checked_add(n).ok_or(ProtocolError::FrameTooLarge(usize::MAX))?;
        if end > self.buf.len() {
            return Err(ProtocolError::Truncated { needed: end, got: self.buf.len() });
        }
        let s = &self.buf[self.at..end];
        self.at = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, ProtocolError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f32(&mut self) -> Result<f32, ProtocolError> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn f64(&mut self) -> Result<f64, ProtocolError> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32s(&mut self, count: usize) -> Result<Vec<f32>, ProtocolError> {
        let bytes = count.checked_mul(4).ok_or(ProtocolError::FrameTooLarge(usize::MAX))?;
        let b = self.take(bytes)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
            .collect())
    }

    fn u32s(&mut self, count: usize) -> Result<Vec<u32>, ProtocolError> {
        let bytes = count.checked_mul(4).ok_or(ProtocolError::FrameTooLarge(usize::MAX))?;
        let b = self.take(bytes)?;
        Ok(b.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.at..];
        self.at = self.buf.len();
        s
    }

    fn done(&self) -> Result<(), ProtocolError> {
        if self.at != self.buf.len() {
            return Err(ProtocolError::Malformed(format!(
                "{} trailing bytes after the body",
                self.buf.len() - self.at
            )));
        }
        Ok(())
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    for &v in vs {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

fn finish_payload(kind: u8, req_id: u64, body: Vec<u8>) -> Vec<u8> {
    let mut payload = Vec::with_capacity(HEADER + body.len());
    payload.push(WIRE_VERSION);
    payload.push(kind);
    payload.extend_from_slice(&[0, 0]); // flags (reserved)
    payload.extend_from_slice(&[0, 0, 0, 0]); // checksum placeholder
    payload.extend_from_slice(&req_id.to_le_bytes());
    payload.extend_from_slice(&body);
    let sum = payload_checksum(&payload);
    payload[4..8].copy_from_slice(&sum.to_le_bytes());
    payload
}

/// Validate the common header; returns `(kind, req_id, body)`.
fn open_payload(payload: &[u8]) -> Result<(u8, u64, &[u8]), ProtocolError> {
    if payload.len() < HEADER {
        return Err(ProtocolError::Truncated { needed: HEADER, got: payload.len() });
    }
    if payload[0] != WIRE_VERSION {
        return Err(ProtocolError::BadVersion(payload[0]));
    }
    let expected = u32::from_le_bytes([payload[4], payload[5], payload[6], payload[7]]);
    let got = payload_checksum(payload);
    if expected != got {
        return Err(ProtocolError::BadChecksum { expected, got });
    }
    let req_id = u64::from_le_bytes([
        payload[8], payload[9], payload[10], payload[11], payload[12], payload[13], payload[14],
        payload[15],
    ]);
    Ok((payload[1], req_id, &payload[HEADER..]))
}

/// Best-effort req-id peek (no checksum/version validation): lets the
/// response path echo the id even when the body is corrupt.
pub fn peek_req_id(payload: &[u8]) -> Option<u64> {
    if payload.len() < HEADER {
        return None;
    }
    Some(u64::from_le_bytes([
        payload[8], payload[9], payload[10], payload[11], payload[12], payload[13], payload[14],
        payload[15],
    ]))
}

// ---------------------------------------------------------------------
// Request / response codecs
// ---------------------------------------------------------------------

/// Encode one request into a frame payload (no length prefix).
pub fn encode_request(req: &StreamRequest, req_id: u64) -> Vec<u8> {
    let (kind, body) = match req {
        StreamRequest::Set { session, rows, channels, values } => {
            let mut b = Vec::with_capacity(12 + 4 * values.len());
            put_u32(&mut b, *session);
            put_u32(&mut b, *rows);
            put_u32(&mut b, *channels);
            put_f32s(&mut b, values);
            (0u8, b)
        }
        StreamRequest::Update { session, rows, channels, values } => {
            let mut b = Vec::with_capacity(12 + 4 * (rows.len() + values.len()));
            put_u32(&mut b, *session);
            put_u32(&mut b, rows.len() as u32);
            put_u32(&mut b, *channels);
            for &r in rows {
                put_u32(&mut b, r);
            }
            put_f32s(&mut b, values);
            (1u8, b)
        }
        StreamRequest::ReplanEdge { session, u, v, w } => {
            let mut b = Vec::with_capacity(20);
            put_u32(&mut b, *session);
            put_u32(&mut b, *u);
            put_u32(&mut b, *v);
            b.extend_from_slice(&w.to_le_bytes());
            (2u8, b)
        }
        StreamRequest::Close { session } => {
            let mut b = Vec::with_capacity(4);
            put_u32(&mut b, *session);
            (3u8, b)
        }
        StreamRequest::Lease { session } => {
            let mut b = Vec::with_capacity(4);
            put_u32(&mut b, *session);
            (4u8, b)
        }
        StreamRequest::OpenGraph { session, n, edges } => {
            let mut b = Vec::with_capacity(12 + 16 * edges.len());
            put_u32(&mut b, *session);
            put_u32(&mut b, *n);
            put_u32(&mut b, edges.len() as u32);
            for &(u, v, w) in edges {
                put_u32(&mut b, u);
                put_u32(&mut b, v);
                b.extend_from_slice(&w.to_le_bytes());
            }
            (5u8, b)
        }
    };
    finish_payload(kind, req_id, body)
}

/// Decode one request payload into `(req_id, request)`.
pub fn decode_request(payload: &[u8]) -> Result<(u64, StreamRequest), ProtocolError> {
    let (kind, req_id, body) = open_payload(payload)?;
    let mut c = Cursor::new(body);
    let req = match kind {
        0 => {
            let session = c.u32()?;
            let rows = c.u32()?;
            let channels = c.u32()?;
            let count = (rows as usize)
                .checked_mul(channels as usize)
                .ok_or_else(|| ProtocolError::Malformed("rows × channels overflows".into()))?;
            let values = c.f32s(count)?;
            StreamRequest::Set { session, rows, channels, values }
        }
        1 => {
            let session = c.u32()?;
            let k = c.u32()? as usize;
            let channels = c.u32()?;
            let rows = c.u32s(k)?;
            // channels = 0 ("infer from session"): values run to the
            // end of the body; otherwise exactly k × channels.
            let values = if channels == 0 {
                let rest = c.rest();
                if rest.len() % 4 != 0 {
                    return Err(ProtocolError::Malformed("update values not 4-byte aligned".into()));
                }
                rest.chunks_exact(4)
                    .map(|b| f32::from_bits(u32::from_le_bytes([b[0], b[1], b[2], b[3]])))
                    .collect()
            } else {
                let count = k
                    .checked_mul(channels as usize)
                    .ok_or_else(|| ProtocolError::Malformed("k × channels overflows".into()))?;
                c.f32s(count)?
            };
            StreamRequest::Update { session, rows, channels, values }
        }
        2 => {
            let session = c.u32()?;
            let u = c.u32()?;
            let v = c.u32()?;
            let w = c.f64()?;
            StreamRequest::ReplanEdge { session, u, v, w }
        }
        3 => StreamRequest::Close { session: c.u32()? },
        4 => StreamRequest::Lease { session: c.u32()? },
        5 => {
            let session = c.u32()?;
            let n = c.u32()?;
            let m = c.u32()? as usize;
            let mut edges = Vec::with_capacity(m.min(MAX_FRAME / 16));
            for _ in 0..m {
                let u = c.u32()?;
                let v = c.u32()?;
                let w = c.f64()?;
                edges.push((u, v, w));
            }
            StreamRequest::OpenGraph { session, n, edges }
        }
        other => return Err(ProtocolError::UnknownKind(other)),
    };
    c.done()?;
    Ok((req_id, req))
}

/// Encode one response into a frame payload (no length prefix).
pub fn encode_response(resp: &StreamResponse, req_id: u64) -> Vec<u8> {
    let (kind, body) = match resp {
        StreamResponse::Output { session, rows, channels, values } => {
            let mut b = Vec::with_capacity(12 + 4 * values.len());
            put_u32(&mut b, *session);
            put_u32(&mut b, *rows);
            put_u32(&mut b, *channels);
            put_f32s(&mut b, values);
            (0u8, b)
        }
        StreamResponse::Closed { session } => {
            let mut b = Vec::with_capacity(4);
            put_u32(&mut b, *session);
            (1u8, b)
        }
        StreamResponse::Rejected { reason, retry_after_hint_ms } => {
            let mut b = Vec::with_capacity(8);
            b.push(reason.code());
            b.extend_from_slice(&[0, 0, 0]); // pad
            put_u32(&mut b, *retry_after_hint_ms);
            (2u8, b)
        }
        StreamResponse::Error { message } => (3u8, message.as_bytes().to_vec()),
    };
    finish_payload(kind, req_id, body)
}

/// Decode one response payload into `(req_id, response)`.
pub fn decode_response(payload: &[u8]) -> Result<(u64, StreamResponse), ProtocolError> {
    let (kind, req_id, body) = open_payload(payload)?;
    let mut c = Cursor::new(body);
    let resp = match kind {
        0 => {
            let session = c.u32()?;
            let rows = c.u32()?;
            let channels = c.u32()?;
            let count = (rows as usize)
                .checked_mul(channels as usize)
                .ok_or_else(|| ProtocolError::Malformed("rows × channels overflows".into()))?;
            let values = c.f32s(count)?;
            StreamResponse::Output { session, rows, channels, values }
        }
        1 => StreamResponse::Closed { session: c.u32()? },
        2 => {
            let head = c.take(4)?;
            let reason = RejectReason::from_code(head[0])?;
            let retry_after_hint_ms = c.u32()?;
            StreamResponse::Rejected { reason, retry_after_hint_ms }
        }
        3 => {
            let message = String::from_utf8(c.rest().to_vec())
                .map_err(|_| ProtocolError::Malformed("error message is not utf-8".into()))?;
            StreamResponse::Error { message }
        }
        other => return Err(ProtocolError::UnknownKind(other)),
    };
    c.done()?;
    Ok((req_id, resp))
}

// ---------------------------------------------------------------------
// Byte-stream framing
// ---------------------------------------------------------------------

/// Write one `[u32 len][payload]` frame and flush.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> std::io::Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

fn read_exact_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<bool, ProtocolError> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(false);
                }
                return Err(ProtocolError::Truncated { needed: buf.len(), got: filled });
            }
            Ok(k) => filled += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(ProtocolError::Io(e.to_string())),
        }
    }
    Ok(true)
}

/// Read one frame's payload. `Ok(None)` on a clean EOF at a frame
/// boundary; EOF mid-frame is [`ProtocolError::Truncated`].
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>, ProtocolError> {
    let mut len_buf = [0u8; 4];
    if !read_exact_or_eof(r, &mut len_buf)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(ProtocolError::FrameTooLarge(len));
    }
    let mut payload = vec![0u8; len];
    if !read_exact_or_eof(r, &mut payload)? && len > 0 {
        return Err(ProtocolError::Truncated { needed: len, got: 0 });
    }
    Ok(Some(payload))
}

// ---------------------------------------------------------------------
// f32-word transport (the in-process queue path)
// ---------------------------------------------------------------------

/// Is this `Vec<f32>` request a typed frame (vs the legacy encoding)?
pub fn is_typed_words(input: &[f32]) -> bool {
    input.first().is_some_and(|w| w.to_bits() == WIRE_MAGIC)
}

/// Pack a frame payload into queue words: `[magic, byte_len, data…]`,
/// 4 payload bytes per data word via `f32::from_bits`.
pub fn payload_to_words(payload: &[u8]) -> Vec<f32> {
    let mut words = Vec::with_capacity(2 + payload.len().div_ceil(4));
    words.push(f32::from_bits(WIRE_MAGIC));
    words.push(f32::from_bits(payload.len() as u32));
    for chunk in payload.chunks(4) {
        let mut b = [0u8; 4];
        b[..chunk.len()].copy_from_slice(chunk);
        words.push(f32::from_bits(u32::from_le_bytes(b)));
    }
    words
}

/// Unpack queue words back into the frame payload.
pub fn words_to_payload(words: &[f32]) -> Result<Vec<u8>, ProtocolError> {
    if words.len() < 2 || !is_typed_words(words) {
        return Err(ProtocolError::Malformed("not a typed-wire word sequence".into()));
    }
    let len = words[1].to_bits() as usize;
    if len > MAX_FRAME {
        return Err(ProtocolError::FrameTooLarge(len));
    }
    let data = &words[2..];
    if data.len() != len.div_ceil(4) {
        return Err(ProtocolError::Truncated { needed: len.div_ceil(4), got: data.len() });
    }
    let mut payload = Vec::with_capacity(len);
    for w in data {
        payload.extend_from_slice(&w.to_bits().to_le_bytes());
    }
    payload.truncate(len);
    Ok(payload)
}

/// Convenience: encode a request straight to queue words.
pub fn request_words(req: &StreamRequest, req_id: u64) -> Vec<f32> {
    payload_to_words(&encode_request(req, req_id))
}

/// Convenience: decode queue words straight to `(req_id, response)`.
pub fn response_from_words(words: &[f32]) -> Result<(u64, StreamResponse), ProtocolError> {
    decode_response(&words_to_payload(words)?)
}

// ---------------------------------------------------------------------
// Legacy-wire shim
// ---------------------------------------------------------------------

/// Parse a non-negative integral f32 below `limit` (the legacy wire's
/// index encoding; integers are exact in f32 up to 2²⁴).
fn parse_index(v: f32, limit: usize, what: &str) -> Result<usize, String> {
    if !v.is_finite() || v < 0.0 || v.fract() != 0.0 || (v as usize) >= limit {
        return Err(format!("{what} {v} invalid (expected an integer in 0..{limit})"));
    }
    Ok(v as usize)
}

/// Session ids on the legacy f32 wire stay exact up to 2²⁴.
const LEGACY_SESSION_LIMIT: usize = 1 << 24;

/// Parse one legacy `[op, session, …]` f32 request into the typed enum
/// — the `--wire legacy` deprecation shim. `n` is the executor's vertex
/// count (the legacy `set` encoding infers `channels` from it, and row
/// indices are bounds-checked against it).
pub fn legacy_to_request(input: &[f32], n: usize) -> Result<StreamRequest, String> {
    if input.len() < 2 {
        return Err("streaming request needs [op, session, …]".to_string());
    }
    let session = parse_index(input[1], LEGACY_SESSION_LIMIT, "session")? as u32;
    if input[0] == 0.0 {
        let payload = &input[2..];
        if n == 0 || payload.is_empty() || payload.len() % n != 0 {
            return Err(crate::ftfi::FtfiError::ShapeMismatch { expected: n, got: payload.len() }
                .to_string());
        }
        let d = payload.len() / n;
        Ok(StreamRequest::Set {
            session,
            rows: n as u32,
            channels: d as u32,
            values: payload.to_vec(),
        })
    } else if input[0] == 1.0 {
        let payload = &input[2..];
        if payload.is_empty() {
            return Err("update needs [k, rows…, values…]".to_string());
        }
        let k = parse_index(payload[0], n + 1, "row count")?;
        if payload.len() < 1 + k {
            return Err(format!("update lists {k} rows but carries {}", payload.len() - 1));
        }
        let mut rows = Vec::with_capacity(k);
        for &r in &payload[1..1 + k] {
            rows.push(parse_index(r, n, "row")? as u32);
        }
        // channels = 0: the executor infers the width from the session
        // (the legacy wire never carried it).
        Ok(StreamRequest::Update { session, rows, channels: 0, values: payload[1 + k..].to_vec() })
    } else if input[0] == 2.0 {
        let payload = &input[2..];
        if payload.len() != 3 {
            return Err(format!("replan needs [u, v, w], got {} values", payload.len()));
        }
        let u = parse_index(payload[0], n, "vertex")? as u32;
        let v = parse_index(payload[1], n, "vertex")? as u32;
        Ok(StreamRequest::ReplanEdge { session, u, v, w: payload[2] as f64 })
    } else {
        Err(format!("unknown streaming opcode {} (0 = set, 1 = update, 2 = replan)", input[0]))
    }
}

// ---------------------------------------------------------------------
// Client-side retry with jittered exponential backoff
// ---------------------------------------------------------------------

/// Backoff policy for [`retry_with_backoff`]: full-jitter exponential
/// delays (`uniform(0, min(max_delay, base·factor^attempt))`) capped by
/// both an attempt count and a total sleep budget.
#[derive(Debug, Clone)]
pub struct BackoffPolicy {
    /// First-retry delay ceiling.
    pub base: Duration,
    /// Exponential growth factor per retry.
    pub factor: f64,
    /// Per-retry delay ceiling.
    pub max_delay: Duration,
    /// Maximum attempts (1 = no retries).
    pub max_attempts: u32,
    /// Total sleep budget across all retries.
    pub budget: Duration,
}

impl Default for BackoffPolicy {
    fn default() -> Self {
        BackoffPolicy {
            base: Duration::from_millis(1),
            factor: 2.0,
            max_delay: Duration::from_millis(50),
            max_attempts: 8,
            budget: Duration::from_secs(2),
        }
    }
}

/// One attempt's verdict inside [`retry_with_backoff`].
pub enum RetryStep<T, E> {
    /// Success — stop retrying.
    Done(T),
    /// Transient failure — back off and try again.
    Retry(E),
    /// Permanent failure — stop immediately.
    Fail(E),
}

/// Run `op` under the policy; returns the final result plus the number
/// of retries performed (for the `retries` metric). Jitter is seeded —
/// the same `(policy, seed)` replays the same delay schedule.
pub fn retry_with_backoff<T, E>(
    policy: &BackoffPolicy,
    seed: u64,
    mut op: impl FnMut(u32) -> RetryStep<T, E>,
) -> (Result<T, E>, u32) {
    let mut rng = Pcg::new(seed, 0xB0FF);
    let mut slept = Duration::ZERO;
    let mut retries = 0u32;
    loop {
        match op(retries) {
            RetryStep::Done(v) => return (Ok(v), retries),
            RetryStep::Fail(e) => return (Err(e), retries),
            RetryStep::Retry(e) => {
                if retries + 1 >= policy.max_attempts.max(1) {
                    return (Err(e), retries);
                }
                let cap = (policy.base.as_secs_f64() * policy.factor.powi(retries as i32))
                    .min(policy.max_delay.as_secs_f64());
                let delay = Duration::from_secs_f64(cap * rng.uniform());
                if slept + delay > policy.budget {
                    return (Err(e), retries);
                }
                std::thread::sleep(delay);
                slept += delay;
                retries += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_request(req: StreamRequest, id: u64) {
        let payload = encode_request(&req, id);
        let (got_id, got) = decode_request(&payload).expect("decode");
        assert_eq!(got_id, id);
        assert_eq!(got, req);
        // And through the word transport.
        let words = payload_to_words(&payload);
        assert!(is_typed_words(&words));
        let back = words_to_payload(&words).expect("unpack");
        assert_eq!(back, payload);
    }

    #[test]
    fn request_roundtrips_all_kinds() {
        roundtrip_request(
            StreamRequest::Set {
                session: 7,
                rows: 3,
                channels: 2,
                values: vec![1.0, -2.5, 0.0, 3.25, f32::MIN_POSITIVE, 9.0],
            },
            42,
        );
        roundtrip_request(
            StreamRequest::Update {
                session: u32::MAX,
                rows: vec![0, 99, 1 << 25], // above the legacy 2²⁴ cap
                channels: 2,
                values: vec![1.0; 6],
            },
            u64::MAX,
        );
        roundtrip_request(
            StreamRequest::ReplanEdge { session: 0, u: 5, v: 6, w: 0.123456789012345 },
            0,
        );
        roundtrip_request(StreamRequest::Close { session: 3 }, 1);
        roundtrip_request(StreamRequest::Lease { session: 4 }, 2);
        roundtrip_request(
            StreamRequest::OpenGraph {
                session: 9,
                n: 4,
                edges: vec![(0, 1, 1.0), (1, 2, 0.25), (2, 3, 7.125e-3)],
            },
            3,
        );
        // Degenerate graphs stay representable (n = 1 has no edges).
        roundtrip_request(StreamRequest::OpenGraph { session: 0, n: 1, edges: vec![] }, 4);
    }

    #[test]
    fn open_graph_truncated_edge_list_fails_typed() {
        let full = encode_request(
            &StreamRequest::OpenGraph { session: 1, n: 3, edges: vec![(0, 1, 1.0), (1, 2, 2.0)] },
            8,
        );
        // Advertise two edges but carry only one (re-checksummed so the
        // body check, not the checksum, is what trips).
        let truncated = finish_payload(5, 8, {
            let mut b = Vec::new();
            put_u32(&mut b, 1); // session
            put_u32(&mut b, 3); // n
            put_u32(&mut b, 2); // edge count
            put_u32(&mut b, 0);
            put_u32(&mut b, 1);
            b.extend_from_slice(&1.0f64.to_le_bytes());
            b
        });
        assert!(matches!(decode_request(&truncated), Err(ProtocolError::Truncated { .. })));
        // And the well-formed frame still decodes.
        assert!(decode_request(&full).is_ok());
    }

    #[test]
    fn response_roundtrips_all_kinds() {
        for (resp, id) in [
            (
                StreamResponse::Output {
                    session: 1,
                    rows: 2,
                    channels: 1,
                    values: vec![1.5, -2.5],
                },
                9u64,
            ),
            (StreamResponse::Closed { session: 8 }, 10),
            (
                StreamResponse::Rejected {
                    reason: RejectReason::Evicted,
                    retry_after_hint_ms: 25,
                },
                11,
            ),
            (StreamResponse::Error { message: "session 3 not initialised".into() }, 12),
        ] {
            let payload = encode_response(&resp, id);
            let (got_id, got) = decode_response(&payload).expect("decode");
            assert_eq!(got_id, id);
            assert_eq!(got, resp);
            let (wid, wresp) = response_from_words(&payload_to_words(&payload)).expect("words");
            assert_eq!(wid, id);
            assert_eq!(wresp, resp);
        }
    }

    #[test]
    fn every_reject_reason_roundtrips() {
        for reason in [
            RejectReason::Backpressure,
            RejectReason::SessionBusy,
            RejectReason::Evicted,
            RejectReason::DeadlineExceeded,
        ] {
            let payload = encode_response(
                &StreamResponse::Rejected { reason, retry_after_hint_ms: 7 },
                1,
            );
            match decode_response(&payload).expect("decode").1 {
                StreamResponse::Rejected { reason: got, retry_after_hint_ms: 7 } => {
                    assert_eq!(got, reason)
                }
                other => panic!("expected Rejected, got {other:?}"),
            }
        }
    }

    #[test]
    fn corrupted_payload_fails_the_checksum() {
        let payload = encode_request(
            &StreamRequest::Set { session: 1, rows: 2, channels: 1, values: vec![1.0, 2.0] },
            5,
        );
        for at in [0usize, 1, 9, HEADER, payload.len() - 1] {
            let mut bad = payload.clone();
            bad[at] ^= 0x40;
            let err = decode_request(&bad).expect_err("corruption must be detected");
            match err {
                ProtocolError::BadChecksum { .. } | ProtocolError::BadVersion(_) => {}
                other => panic!("byte {at}: expected checksum/version error, got {other:?}"),
            }
        }
    }

    #[test]
    fn malformed_payloads_fail_typed() {
        // Truncated header.
        assert!(matches!(
            decode_request(&[1, 0, 0]),
            Err(ProtocolError::Truncated { .. })
        ));
        // Unknown kind (re-checksummed so the kind check is reached).
        let bogus = finish_payload(9, 1, vec![]);
        assert!(matches!(decode_request(&bogus), Err(ProtocolError::UnknownKind(9))));
        // Bad version.
        let mut payload = encode_request(&StreamRequest::Close { session: 0 }, 1);
        payload[0] = 99;
        let sum = payload_checksum(&payload);
        payload[4..8].copy_from_slice(&sum.to_le_bytes());
        assert!(matches!(decode_request(&payload), Err(ProtocolError::BadVersion(99))));
        // Body shorter than the advertised counts.
        let truncated = finish_payload(0, 1, {
            let mut b = Vec::new();
            put_u32(&mut b, 0); // session
            put_u32(&mut b, 100); // rows
            put_u32(&mut b, 100); // channels — but no values follow
            b
        });
        assert!(matches!(decode_request(&truncated), Err(ProtocolError::Truncated { .. })));
        // Trailing garbage after a well-formed body.
        let trailing = finish_payload(3, 1, {
            let mut b = Vec::new();
            put_u32(&mut b, 0);
            b.push(0xAB);
            b
        });
        assert!(matches!(decode_request(&trailing), Err(ProtocolError::Malformed(_))));
    }

    #[test]
    fn frame_io_roundtrips_and_reports_clean_eof() {
        let a = encode_request(&StreamRequest::Lease { session: 1 }, 7);
        let b = encode_response(&StreamResponse::Closed { session: 1 }, 7);
        let mut wire = Vec::new();
        write_frame(&mut wire, &a).unwrap();
        write_frame(&mut wire, &b).unwrap();
        let mut r = &wire[..];
        assert_eq!(read_frame(&mut r).unwrap().as_deref(), Some(&a[..]));
        assert_eq!(read_frame(&mut r).unwrap().as_deref(), Some(&b[..]));
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF at a frame boundary");
        // EOF mid-frame is truncation, not a clean close.
        let mut torn = &wire[..wire.len() - 3];
        assert!(read_frame(&mut torn).unwrap().is_some());
        assert!(matches!(read_frame(&mut torn), Err(ProtocolError::Truncated { .. })));
        // A hostile length prefix fails fast.
        let mut huge = &[0xFF, 0xFF, 0xFF, 0xFF][..];
        assert!(matches!(read_frame(&mut huge), Err(ProtocolError::FrameTooLarge(_))));
    }

    #[test]
    fn word_transport_is_lossless_for_all_byte_lengths() {
        for len in 0..9usize {
            let payload: Vec<u8> =
                (0..len as u8).map(|b| b.wrapping_mul(37).wrapping_add(1)).collect();
            let words = payload_to_words(&payload);
            assert_eq!(words_to_payload(&words).unwrap(), payload, "len {len}");
        }
        // Legacy requests never look typed.
        assert!(!is_typed_words(&[0.0, 1.0, 2.0]));
        assert!(!is_typed_words(&[2.0, 0.0, 1.0, 2.0, 0.5]));
        assert!(!is_typed_words(&[]));
        // Word-count mismatch is typed, not a panic.
        let mut words = payload_to_words(&[1, 2, 3, 4, 5]);
        words.pop();
        assert!(matches!(words_to_payload(&words), Err(ProtocolError::Truncated { .. })));
    }

    #[test]
    fn legacy_shim_parses_the_old_wire() {
        let n = 8;
        // set
        let mut set = vec![0.0f32, 3.0];
        set.extend((0..n).map(|i| i as f32));
        assert_eq!(
            legacy_to_request(&set, n).unwrap(),
            StreamRequest::Set {
                session: 3,
                rows: 8,
                channels: 1,
                values: (0..n).map(|i| i as f32).collect(),
            }
        );
        // update (channels = 0: infer from session)
        let upd = vec![1.0f32, 2.0, 2.0, 1.0, 5.0, 0.25, -0.5];
        assert_eq!(
            legacy_to_request(&upd, n).unwrap(),
            StreamRequest::Update {
                session: 2,
                rows: vec![1, 5],
                channels: 0,
                values: vec![0.25, -0.5],
            }
        );
        // replan
        let rep = vec![2.0f32, 0.0, 1.0, 2.0, 0.75];
        assert_eq!(
            legacy_to_request(&rep, n).unwrap(),
            StreamRequest::ReplanEdge { session: 0, u: 1, v: 2, w: 0.75 }
        );
        // Malformed cases fail with strings, never panic.
        assert!(legacy_to_request(&[], n).is_err());
        assert!(legacy_to_request(&[3.0, 0.0, 1.0], n).is_err()); // unknown opcode
        assert!(legacy_to_request(&[1.0, 0.0, 2.5, 1.0], n).is_err()); // fractional k
        assert!(legacy_to_request(&[1.0, 0.0, 1.0, 99.0, 1.0], n).is_err()); // row ≥ n
        assert!(legacy_to_request(&[2.0, 0.0, 0.0, 1.0], n).is_err()); // truncated replan
        assert!(legacy_to_request(&[0.0, 0.0, 1.0, 2.0, 3.0], n).is_err()); // len % n != 0
        assert!(legacy_to_request(&[1.0, f32::NAN, 0.0], n).is_err()); // NaN session
    }

    #[test]
    fn peek_req_id_survives_body_corruption() {
        let mut payload = encode_request(&StreamRequest::Close { session: 1 }, 0xDEAD_BEEF);
        let last = payload.len() - 1;
        payload[last] ^= 0xFF; // corrupt the body, not the id
        assert!(decode_request(&payload).is_err());
        assert_eq!(peek_req_id(&payload), Some(0xDEAD_BEEF));
        assert_eq!(peek_req_id(&[1, 2, 3]), None);
    }

    #[test]
    fn backoff_retries_are_capped_and_seeded() {
        let policy = BackoffPolicy {
            base: Duration::from_micros(50),
            factor: 2.0,
            max_delay: Duration::from_micros(400),
            max_attempts: 4,
            budget: Duration::from_secs(1),
        };
        // Always-transient: exhausts the attempt cap.
        let (res, retries) = retry_with_backoff::<(), _>(&policy, 7, |_| RetryStep::Retry("full"));
        assert_eq!(res, Err("full"));
        assert_eq!(retries, 3, "max_attempts = 4 ⇒ 3 retries");
        // Succeeds on the third attempt.
        let (res, retries) = retry_with_backoff(&policy, 7, |a| {
            if a == 2 {
                RetryStep::Done(a)
            } else {
                RetryStep::Retry("again")
            }
        });
        assert_eq!(res, Ok(2));
        assert_eq!(retries, 2);
        // Fatal errors stop immediately.
        let (res, retries) = retry_with_backoff::<(), _>(&policy, 7, |_| RetryStep::Fail("perm"));
        assert_eq!(res, Err("perm"));
        assert_eq!(retries, 0);
        // A zero budget forbids any sleep ⇒ at most one attempt's retry.
        let broke = BackoffPolicy { budget: Duration::ZERO, ..policy };
        let t0 = std::time::Instant::now();
        let (res, retries) = retry_with_backoff::<(), _>(&broke, 7, |_| RetryStep::Retry("x"));
        assert_eq!(res, Err("x"));
        assert_eq!(retries, 0);
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Standard FNV-1a 32-bit test vectors.
        assert_eq!(fnv1a(b""), 0x811c_9dc5);
        assert_eq!(fnv1a(b"a"), 0xe40c_292c);
        assert_eq!(fnv1a(b"foobar"), 0xbf9c_f968);
    }
}
