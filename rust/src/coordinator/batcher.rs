//! Dynamic batching: fuse queued requests into fixed-size model batches
//! under a fill-or-timeout policy (the standard latency/throughput knob
//! of serving systems).

use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Executes one fused batch. Implementations: the PJRT model runner
/// ([`crate::runtime::topvit::TopVitExecutor`]) and the mock used by unit
/// tests. Deliberately NOT `Send`: PJRT executables hold `Rc` internals,
/// so each executor is constructed inside (and never leaves) its worker
/// thread — the `Send` boundary is the factory closure in
/// [`crate::coordinator::InferenceServer::start`].
pub trait BatchExecutor: 'static {
    /// The fixed batch size the compiled executable expects; the batcher
    /// pads short batches up to this.
    fn max_batch(&self) -> usize;
    /// Run `inputs.len() ≤ max_batch` flattened inputs; must return one
    /// output per input (padding handled inside).
    fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String>;

    /// Per-request execution: one `Result` per input, so a malformed
    /// request can fail alone without poisoning its batch-mates. The
    /// default fans a batch-level [`BatchExecutor::execute`] error out
    /// to every request (the only option for executors — like the
    /// fixed-shape PJRT model runner — that genuinely fail as a unit);
    /// executors that can isolate failures (the field executors)
    /// override it.
    fn execute_each(&self, inputs: &[Vec<f32>]) -> Vec<Result<Vec<f32>, String>> {
        match self.execute(inputs) {
            Ok(outputs) => outputs.into_iter().map(Ok).collect(),
            Err(e) => inputs.iter().map(|_| Err(e.clone())).collect(),
        }
    }

    /// Fusion grouping key for deadline-shed accounting. Requests in
    /// one dispatch batch that share a `Some` key are executed as one
    /// fused group by `execute_each` (e.g. same-session streaming
    /// updates), so the batcher treats them as a unit: the group is
    /// shed only when *every* member has aged past the deadline — a
    /// mixed group executes whole, aged members riding their fresh
    /// group-mates' fused pass — and a shed group counts **once** in
    /// `requests_shed`. `None` (the default) keeps the pre-fusion
    /// per-request shed semantics.
    fn fuse_key(&self, _input: &[f32]) -> Option<u64> {
        None
    }
}

/// Shared executors: workers wrap one *stateful* executor (e.g. the
/// streaming session table, or an expensive ensemble backend) in an
/// `Arc` instead of rebuilding per worker — the state stays global to
/// the server while every worker thread dispatches into it.
impl<T: BatchExecutor> BatchExecutor for std::sync::Arc<T> {
    fn max_batch(&self) -> usize {
        (**self).max_batch()
    }
    fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        (**self).execute(inputs)
    }
    fn execute_each(&self, inputs: &[Vec<f32>]) -> Vec<Result<Vec<f32>, String>> {
        (**self).execute_each(inputs)
    }
    fn fuse_key(&self, input: &[f32]) -> Option<u64> {
        (**self).fuse_key(input)
    }
}

/// Batcher policy.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    pub batch_size: usize,
    pub batch_timeout: Duration,
    /// Deadline-based load shedding: a request that has been queued
    /// longer than this by dispatch time is answered with a typed shed
    /// error instead of being executed (its batch-mates still run).
    /// `None` disables shedding (the pre-PR-9 behaviour).
    pub shed_after: Option<Duration>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            batch_size: 8,
            batch_timeout: Duration::from_millis(2),
            shed_after: None,
        }
    }
}

/// One queued request: payload + response channel.
pub struct PendingRequest {
    pub input: Vec<f32>,
    pub respond: mpsc::Sender<Result<Vec<f32>, String>>,
    pub enqueued_at: Instant,
}

/// Pulls requests from `rx`, forms batches under the fill-or-timeout
/// policy and returns them to the caller loop. Pure policy — no threads —
/// so it is directly unit-testable.
pub struct Batcher {
    cfg: BatcherConfig,
}

impl Batcher {
    /// `batch_size` is clamped to ≥ 1 (a zero-sized batch could never
    /// release a request) — same convention as the executors' `max_batch`.
    pub fn new(mut cfg: BatcherConfig) -> Self {
        cfg.batch_size = cfg.batch_size.max(1);
        Batcher { cfg }
    }

    /// Block until at least one request is available, then gather more
    /// until the batch is full or the timeout since the *first* request
    /// elapses. Returns `None` when the channel is closed and drained.
    pub fn next_batch(&self, rx: &mpsc::Receiver<PendingRequest>) -> Option<Vec<PendingRequest>> {
        let first = rx.recv().ok()?;
        let deadline = Instant::now() + self.cfg.batch_timeout;
        let mut batch = vec![first];
        while batch.len() < self.cfg.batch_size {
            // `saturating_duration_since` instead of `deadline - now`:
            // the clock can pass `deadline` between a check and the
            // subtraction, and Instant subtraction panics on underflow.
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break;
            }
            match rx.recv_timeout(remaining) {
                Ok(req) => batch.push(req),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }

    /// Run one batch through the executor and fan responses out —
    /// per request, so one bad request cannot fail its batch-mates
    /// unless the executor genuinely fails as a unit.
    ///
    /// Robustness duties (PR 9): requests past their `shed_after`
    /// deadline are answered with a typed shed error *before* the
    /// executor runs, and a panicking executor is contained with
    /// `catch_unwind` so every request still receives exactly one
    /// response (an error, never silence).
    pub fn dispatch(
        &self,
        batch: Vec<PendingRequest>,
        exec: &dyn BatchExecutor,
        metrics: &super::metrics::MetricsRegistry,
    ) {
        let mut live: Vec<PendingRequest> = Vec::with_capacity(batch.len());
        if let Some(limit) = self.cfg.shed_after {
            // Shed accounting is fuse-group aware: requests sharing a
            // `fuse_key` execute as one fused pass downstream, so the
            // group sheds as a unit — only when every member aged (a
            // mixed group executes whole; its aged members ride the
            // fused pass) — and a shed group counts once. Ages come from
            // one `now` through `saturating_duration_since`, so a
            // request whose `enqueued_at` sits in the future (clock
            // skew, test injection) reads age zero instead of panicking
            // on Duration underflow.
            let now = Instant::now();
            let mut group_all_aged: std::collections::BTreeMap<u64, bool> =
                std::collections::BTreeMap::new();
            let flags: Vec<(bool, Option<u64>)> = batch
                .iter()
                .map(|req| {
                    let aged = now.saturating_duration_since(req.enqueued_at) > limit;
                    let key = exec.fuse_key(&req.input);
                    if let Some(k) = key {
                        group_all_aged.entry(k).and_modify(|a| *a &= aged).or_insert(aged);
                    }
                    (aged, key)
                })
                .collect();
            let mut shed_groups: std::collections::BTreeSet<u64> =
                std::collections::BTreeSet::new();
            for (req, (aged, key)) in batch.into_iter().zip(flags) {
                let shed = match key {
                    Some(k) => group_all_aged[&k],
                    None => aged,
                };
                if shed {
                    match key {
                        Some(k) => {
                            if shed_groups.insert(k) {
                                metrics.record_shed();
                            }
                        }
                        None => metrics.record_shed(),
                    }
                    metrics.queue_exit();
                    let _ = req.respond.send(Err(format!(
                        "{}deadline exceeded in queue",
                        crate::coordinator::protocol::ERR_SHED_PREFIX
                    )));
                } else {
                    live.push(req);
                }
            }
        } else {
            live = batch;
        }
        if live.is_empty() {
            return;
        }
        let inputs: Vec<Vec<f32>> = live.iter().map(|r| r.input.clone()).collect();
        let t0 = Instant::now();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            exec.execute_each(&inputs)
        }));
        let exec_secs = t0.elapsed().as_secs_f64();
        metrics.record_batch(live.len(), exec_secs);
        let results = match outcome {
            Ok(results) => {
                debug_assert_eq!(results.len(), live.len());
                results
            }
            Err(cause) => {
                // A worker panic must not drop response channels on the
                // floor: fan a typed error out to every request so the
                // exactly-one-response invariant holds.
                metrics.record_worker_panic();
                let what = cause
                    .downcast_ref::<&str>()
                    .map(|s| (*s).to_string())
                    .or_else(|| cause.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "unknown panic payload".to_string());
                live.iter().map(|_| Err(format!("worker panic: {what}"))).collect()
            }
        };
        for (req, res) in live.into_iter().zip(results) {
            if res.is_ok() {
                metrics.record_latency(req.enqueued_at.elapsed().as_secs_f64());
            }
            metrics.queue_exit();
            let _ = req.respond.send(res);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::MetricsRegistry;

    struct Echo {
        batch: usize,
    }

    impl BatchExecutor for Echo {
        fn max_batch(&self) -> usize {
            self.batch
        }
        fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
            Ok(inputs.iter().map(|v| v.iter().map(|x| x * 2.0).collect()).collect())
        }
    }

    fn req(v: f32) -> (PendingRequest, mpsc::Receiver<Result<Vec<f32>, String>>) {
        let (tx, rx) = mpsc::channel();
        (
            PendingRequest { input: vec![v], respond: tx, enqueued_at: Instant::now() },
            rx,
        )
    }

    #[test]
    fn batch_fills_to_size() {
        let (tx, rx) = mpsc::channel();
        for i in 0..5 {
            let (r, _keep) = req(i as f32);
            // Keep the response receiver alive via leak-free drop: the
            // batcher only groups here, no dispatch.
            std::mem::forget(_keep);
            tx.send(r).unwrap();
        }
        let b = Batcher::new(BatcherConfig {
            batch_size: 3,
            batch_timeout: Duration::from_millis(50),
            shed_after: None,
        });
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 3);
        let batch2 = b.next_batch(&rx).unwrap();
        assert_eq!(batch2.len(), 2); // remaining after timeout
    }

    #[test]
    fn batch_times_out_short() {
        let (tx, rx) = mpsc::channel();
        let (r, _keep) = req(1.0);
        std::mem::forget(_keep);
        tx.send(r).unwrap();
        let b = Batcher::new(BatcherConfig {
            batch_size: 64,
            batch_timeout: Duration::from_millis(5),
            shed_after: None,
        });
        let t0 = Instant::now();
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    /// Boundary case: exactly `batch_size` requests already queued — the
    /// batch must return full immediately, not wait out the timeout.
    #[test]
    fn exact_fill_does_not_wait_for_timeout() {
        let (tx, rx) = mpsc::channel();
        for i in 0..4 {
            let (r, keep) = req(i as f32);
            std::mem::forget(keep);
            tx.send(r).unwrap();
        }
        let b = Batcher::new(BatcherConfig {
            batch_size: 4,
            batch_timeout: Duration::from_secs(10),
            shed_after: None,
        });
        let t0 = Instant::now();
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 4);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "a full batch must not wait for the timeout"
        );
    }

    /// Boundary case: fewer requests than `batch_size` — the batcher
    /// must hold the partial batch for the whole timeout window (giving
    /// stragglers a chance) and then release it as-is.
    #[test]
    fn timeout_releases_partial_batch_after_full_window() {
        let (tx, rx) = mpsc::channel();
        for i in 0..3 {
            let (r, keep) = req(i as f32);
            std::mem::forget(keep);
            tx.send(r).unwrap();
        }
        let b = Batcher::new(BatcherConfig {
            batch_size: 8,
            batch_timeout: Duration::from_millis(40),
            shed_after: None,
        });
        let t0 = Instant::now();
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 3);
        assert!(
            t0.elapsed() >= Duration::from_millis(35),
            "partial batch released after {:?} — before the timeout window",
            t0.elapsed()
        );
        drop(tx); // kept alive so the wait could not end on Disconnected
    }

    #[test]
    fn closed_channel_returns_none() {
        let (tx, rx) = mpsc::channel::<PendingRequest>();
        drop(tx);
        let b = Batcher::new(BatcherConfig::default());
        assert!(b.next_batch(&rx).is_none());
    }

    #[test]
    fn dispatch_fans_out_responses() {
        let b = Batcher::new(BatcherConfig::default());
        let metrics = MetricsRegistry::new();
        let (r1, rx1) = req(1.0);
        let (r2, rx2) = req(3.0);
        b.dispatch(vec![r1, r2], &Echo { batch: 8 }, &metrics);
        assert_eq!(rx1.recv().unwrap().unwrap(), vec![2.0]);
        assert_eq!(rx2.recv().unwrap().unwrap(), vec![6.0]);
        let snap = metrics.snapshot();
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.batches, 1);
    }

    /// Regression for the deadline race: if the producer keeps feeding
    /// requests right at the timeout boundary, `next_batch` used to
    /// compute `deadline - now` after a staleness check, and the clock
    /// could pass `deadline` in between — panicking on Duration
    /// underflow. With a zero timeout every iteration sits exactly on
    /// the boundary, so this loop would have tripped the old code.
    #[test]
    fn zero_timeout_boundary_never_panics() {
        let b = Batcher::new(BatcherConfig {
            batch_size: 64,
            batch_timeout: Duration::ZERO,
            shed_after: None,
        });
        for round in 0..200 {
            let (tx, rx) = mpsc::channel();
            for i in 0..4 {
                let (r, keep) = req(i as f32);
                std::mem::forget(keep);
                tx.send(r).unwrap();
            }
            let batch = b.next_batch(&rx).expect("queued requests present");
            assert!(!batch.is_empty(), "round {round}: boundary batch must not be empty");
        }
    }

    /// Requests older than `shed_after` are answered with a typed shed
    /// error before execution; fresh batch-mates still run normally.
    #[test]
    fn dispatch_sheds_aged_requests_only() {
        let b = Batcher::new(BatcherConfig {
            batch_size: 8,
            batch_timeout: Duration::from_millis(1),
            shed_after: Some(Duration::from_millis(20)),
        });
        let metrics = MetricsRegistry::new();
        let (mut stale, stale_rx) = req(1.0);
        stale.enqueued_at = Instant::now() - Duration::from_millis(200);
        let (fresh, fresh_rx) = req(3.0);
        b.dispatch(vec![stale, fresh], &Echo { batch: 8 }, &metrics);
        let shed = stale_rx.recv().unwrap().unwrap_err();
        assert!(
            shed.starts_with(crate::coordinator::protocol::ERR_SHED_PREFIX),
            "shed error must carry the typed prefix, got: {shed}"
        );
        assert_eq!(fresh_rx.recv().unwrap().unwrap(), vec![6.0]);
        let snap = metrics.snapshot();
        assert_eq!(snap.requests_shed, 1);
        assert_eq!(snap.requests, 1, "only the fresh request counts as served");
    }

    /// An executor whose first input word is the fuse key: models the
    /// streaming executor's same-session update grouping.
    struct FusedEcho;

    impl BatchExecutor for FusedEcho {
        fn max_batch(&self) -> usize {
            8
        }
        fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
            Ok(inputs.iter().map(|v| v.iter().map(|x| x * 2.0).collect()).collect())
        }
        fn fuse_key(&self, input: &[f32]) -> Option<u64> {
            Some(input[0] as u64)
        }
    }

    /// Fused-group shed accounting: a group sheds as a unit only when
    /// *every* member aged past the deadline, and a shed group counts
    /// once in `requests_shed` — not once per member. A mixed group
    /// (one stale + one fresh member) executes whole: the stale member
    /// rides its fresh group-mate's fused pass.
    #[test]
    fn fused_group_sheds_as_a_unit_and_counts_once() {
        let b = Batcher::new(BatcherConfig {
            batch_size: 8,
            batch_timeout: Duration::from_millis(1),
            shed_after: Some(Duration::from_millis(20)),
        });
        let metrics = MetricsRegistry::new();
        // Group 1: both members stale → shed together, counted once.
        let (mut a1, a1_rx) = req(1.0);
        a1.enqueued_at = Instant::now() - Duration::from_millis(200);
        let (mut a2, a2_rx) = req(1.0);
        a2.enqueued_at = Instant::now() - Duration::from_millis(300);
        // Group 2: one stale, one fresh → executes whole.
        let (mut b1, b1_rx) = req(2.0);
        b1.enqueued_at = Instant::now() - Duration::from_millis(200);
        let (b2, b2_rx) = req(2.0);
        b.dispatch(vec![a1, a2, b1, b2], &FusedEcho, &metrics);
        for rx in [a1_rx, a2_rx] {
            let e = rx.recv().unwrap().unwrap_err();
            assert!(
                e.starts_with(crate::coordinator::protocol::ERR_SHED_PREFIX),
                "all-aged group member must shed typed, got: {e}"
            );
        }
        assert_eq!(
            b1_rx.recv().unwrap().unwrap(),
            vec![4.0],
            "aged member of a mixed group must ride its group-mates' pass"
        );
        assert_eq!(b2_rx.recv().unwrap().unwrap(), vec![4.0]);
        let snap = metrics.snapshot();
        assert_eq!(snap.requests_shed, 1, "a shed fused group counts once");
        assert_eq!(snap.requests, 2, "the mixed group executes whole");
    }

    /// Saturating age arithmetic: a request stamped with a *future*
    /// `enqueued_at` (clock skew, test injection) must read as age zero
    /// — neither shed nor a Duration-underflow panic — even under a
    /// zero shed deadline, where every age comparison sits exactly on
    /// the boundary.
    #[test]
    fn future_enqueued_at_reads_age_zero_and_is_never_shed() {
        let b = Batcher::new(BatcherConfig {
            batch_size: 8,
            batch_timeout: Duration::from_millis(1),
            shed_after: Some(Duration::ZERO),
        });
        let metrics = MetricsRegistry::new();
        let (mut future, future_rx) = req(3.0);
        future.enqueued_at = Instant::now() + Duration::from_secs(3600);
        b.dispatch(vec![future], &Echo { batch: 8 }, &metrics);
        assert_eq!(
            future_rx.recv().unwrap().unwrap(),
            vec![6.0],
            "a future-stamped request is age zero, not shed"
        );
        let snap = metrics.snapshot();
        assert_eq!(snap.requests_shed, 0);
        assert_eq!(snap.requests, 1);
    }

    /// A panicking executor must not swallow responses: every request
    /// in the batch receives an error and the panic counter moves.
    #[test]
    fn dispatch_contains_worker_panics() {
        struct Blows;
        impl BatchExecutor for Blows {
            fn max_batch(&self) -> usize {
                4
            }
            fn execute(&self, _: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
                panic!("injected for test");
            }
        }
        let b = Batcher::new(BatcherConfig::default());
        let metrics = MetricsRegistry::new();
        let (r1, rx1) = req(1.0);
        let (r2, rx2) = req(2.0);
        // Silence the default panic hook for the intentional panic so
        // test output stays readable; restore it afterwards.
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        b.dispatch(vec![r1, r2], &Blows, &metrics);
        std::panic::set_hook(hook);
        let e1 = rx1.recv().unwrap().unwrap_err();
        let e2 = rx2.recv().unwrap().unwrap_err();
        assert!(e1.contains("worker panic"), "got: {e1}");
        assert!(e2.contains("injected for test"), "got: {e2}");
        assert_eq!(metrics.snapshot().worker_panics, 1);
    }

    #[test]
    fn dispatch_propagates_errors() {
        struct Fail;
        impl BatchExecutor for Fail {
            fn max_batch(&self) -> usize {
                4
            }
            fn execute(&self, _: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
                Err("boom".into())
            }
        }
        let b = Batcher::new(BatcherConfig::default());
        let metrics = MetricsRegistry::new();
        let (r, rx) = req(1.0);
        b.dispatch(vec![r], &Fail, &metrics);
        assert!(rx.recv().unwrap().is_err());
    }
}
