//! The inference server: bounded submission queue (backpressure), a
//! collector thread forming batches, and a worker pool executing them.

use super::batcher::{BatchExecutor, Batcher, BatcherConfig, PendingRequest};
use super::metrics::MetricsRegistry;
// Mutex and the closing flag come from the crate-wide sync shim so loom
// builds model the worker handoff; Arc and mpsc stay `std` deliberately
// (see `crate::sync` module docs).
use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::Mutex;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

/// Submission/response errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServerError {
    /// The bounded queue is full — caller should back off and retry.
    Backpressure,
    /// Server shutting down.
    Closed,
    /// Model execution failed.
    Exec(String),
}

impl std::fmt::Display for ServerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServerError::Backpressure => write!(f, "queue full (backpressure)"),
            ServerError::Closed => write!(f, "server closed"),
            ServerError::Exec(e) => write!(f, "execution failed: {e}"),
        }
    }
}

impl std::error::Error for ServerError {}

/// The server boundary for the FTFI stack: typed integration errors
/// become execution failures on the response path — a malformed request
/// fails its own response without taking a worker thread down.
impl From<crate::ftfi::FtfiError> for ServerError {
    fn from(e: crate::ftfi::FtfiError) -> Self {
        ServerError::Exec(e.to_string())
    }
}

/// A running inference server. Dropping it (or calling
/// [`InferenceServer::shutdown`]) drains the queue and joins the threads.
pub struct InferenceServer {
    submit_tx: mpsc::SyncSender<PendingRequest>,
    metrics: Arc<MetricsRegistry>,
    collector: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    closing: Arc<AtomicBool>,
}

impl InferenceServer {
    /// Start with one execution thread per factory. Each worker *builds*
    /// its executor inside its own thread (PJRT clients/executables are
    /// not `Send` — they hold `Rc` internals — so construction must
    /// happen thread-locally) and round-robins over a shared batch
    /// channel.
    pub fn start(
        factories: Vec<Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>>,
        cfg: BatcherConfig,
        queue_capacity: usize,
    ) -> Self {
        // lint: allow(unchecked-panic) — a documented construction
        // precondition: a server with zero workers can never serve, and
        // failing at startup (not at first submit) is the useful spot.
        assert!(!factories.is_empty());
        let metrics = Arc::new(MetricsRegistry::new());
        let (submit_tx, submit_rx) = mpsc::sync_channel::<PendingRequest>(queue_capacity);
        let (batch_tx, batch_rx) = mpsc::channel::<Vec<PendingRequest>>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let closing = Arc::new(AtomicBool::new(false));

        // Collector: requests → batches.
        let collector_cfg = cfg.clone();
        let collector = std::thread::Builder::new()
            .name("ftfi-collector".into())
            .spawn(move || {
                let batcher = Batcher::new(collector_cfg);
                while let Some(batch) = batcher.next_batch(&submit_rx) {
                    if batch_tx.send(batch).is_err() {
                        break;
                    }
                }
            })
            // lint: allow(unchecked-panic) — OS thread-spawn failure at
            // server startup is unrecoverable for the caller anyway.
            .expect("spawn collector");

        // Workers: batches → responses.
        let workers = factories
            .into_iter()
            .enumerate()
            .map(|(i, factory)| {
                let rx = Arc::clone(&batch_rx);
                let m = Arc::clone(&metrics);
                let cfg = cfg.clone();
                std::thread::Builder::new()
                    .name(format!("ftfi-worker-{i}"))
                    .spawn(move || {
                        let exec = factory();
                        let batcher = Batcher::new(cfg);
                        loop {
                            // A poisoned receiver lock means a sibling
                            // worker died mid-recv; exit cleanly instead
                            // of cascading the panic through the pool.
                            let batch = match rx.lock() {
                                Ok(guard) => guard.recv(),
                                Err(_) => break,
                            };
                            match batch {
                                Ok(b) => batcher.dispatch(b, exec.as_ref(), &m),
                                Err(_) => break,
                            }
                        }
                    })
                    // lint: allow(unchecked-panic) — OS thread-spawn
                    // failure at server startup is unrecoverable.
                    .expect("spawn worker")
            })
            .collect();

        InferenceServer { submit_tx, metrics, collector: Some(collector), workers, closing }
    }

    /// Submit one request; returns a handle to await the response.
    pub fn submit(&self, input: Vec<f32>) -> Result<ResponseHandle, ServerError> {
        if self.closing.load(Ordering::Relaxed) {
            return Err(ServerError::Closed);
        }
        let (tx, rx) = mpsc::channel();
        let req = PendingRequest { input, respond: tx, enqueued_at: Instant::now() };
        match self.submit_tx.try_send(req) {
            Ok(()) => Ok(ResponseHandle { rx }),
            Err(mpsc::TrySendError::Full(_)) => Err(ServerError::Backpressure),
            Err(mpsc::TrySendError::Disconnected(_)) => Err(ServerError::Closed),
        }
    }

    /// Blocking submit: waits under backpressure instead of failing.
    pub fn submit_blocking(&self, input: Vec<f32>) -> Result<ResponseHandle, ServerError> {
        let (tx, rx) = mpsc::channel();
        let req = PendingRequest { input, respond: tx, enqueued_at: Instant::now() };
        self.submit_tx.send(req).map_err(|_| ServerError::Closed)?;
        Ok(ResponseHandle { rx })
    }

    /// Current metrics snapshot.
    pub fn metrics(&self) -> super::metrics::MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Graceful shutdown: stop accepting, drain, join.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.closing.store(true, Ordering::Relaxed);
        // Replace the sender so the collector's recv unblocks once all
        // outstanding handles are gone.
        let (dummy_tx, _) = mpsc::sync_channel(1);
        let old = std::mem::replace(&mut self.submit_tx, dummy_tx);
        drop(old);
        if let Some(c) = self.collector.take() {
            let _ = c.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        if self.collector.is_some() {
            self.shutdown_inner();
        }
    }
}

/// Await handle for one submitted request.
pub struct ResponseHandle {
    rx: mpsc::Receiver<Result<Vec<f32>, String>>,
}

impl ResponseHandle {
    /// Block until the response arrives.
    pub fn wait(self) -> Result<Vec<f32>, ServerError> {
        match self.rx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(e)) => Err(ServerError::Exec(e)),
            Err(_) => Err(ServerError::Closed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    struct Doubler;
    impl BatchExecutor for Doubler {
        fn max_batch(&self) -> usize {
            8
        }
        fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
            Ok(inputs.iter().map(|v| v.iter().map(|x| x * 2.0).collect()).collect())
        }
    }

    fn cfg() -> BatcherConfig {
        BatcherConfig { batch_size: 4, batch_timeout: Duration::from_millis(1) }
    }

    #[test]
    fn ftfi_error_converts_to_exec() {
        let e: ServerError = crate::ftfi::FtfiError::DisconnectedGraph.into();
        match e {
            ServerError::Exec(msg) => assert!(msg.contains("disconnected"), "{msg}"),
            other => panic!("expected Exec, got {other:?}"),
        }
    }

    #[test]
    fn end_to_end_roundtrip() {
        let server = InferenceServer::start(vec![Box::new(|| Box::new(Doubler) as Box<dyn BatchExecutor>)], cfg(), 64);
        let handles: Vec<_> =
            (0..20).map(|i| server.submit_blocking(vec![i as f32]).unwrap()).collect();
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.wait().unwrap(), vec![2.0 * i as f32]);
        }
        let m = server.metrics();
        assert_eq!(m.requests, 20);
        assert!(m.batches <= 20);
        server.shutdown();
    }

    #[test]
    fn multiple_workers() {
        let server = InferenceServer::start(
            vec![
                Box::new(|| Box::new(Doubler) as Box<dyn BatchExecutor>),
                Box::new(|| Box::new(Doubler) as Box<dyn BatchExecutor>),
            ],
            cfg(),
            64,
        );
        let handles: Vec<_> =
            (0..50).map(|i| server.submit_blocking(vec![i as f32]).unwrap()).collect();
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.wait().unwrap(), vec![2.0 * i as f32]);
        }
        server.shutdown();
    }

    #[test]
    fn backpressure_on_full_queue() {
        struct Slow;
        impl BatchExecutor for Slow {
            fn max_batch(&self) -> usize {
                1
            }
            fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
                std::thread::sleep(Duration::from_millis(30));
                Ok(inputs.to_vec())
            }
        }
        let server = InferenceServer::start(
            vec![Box::new(|| Box::new(Slow) as Box<dyn BatchExecutor>)],
            BatcherConfig { batch_size: 1, batch_timeout: Duration::from_millis(0) },
            2,
        );
        // Flood: some submissions must hit Backpressure.
        let mut saw_backpressure = false;
        let mut handles = Vec::new();
        for i in 0..32 {
            match server.submit(vec![i as f32]) {
                Ok(h) => handles.push(h),
                Err(ServerError::Backpressure) => {
                    saw_backpressure = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(saw_backpressure, "queue never filled");
        for h in handles {
            let _ = h.wait();
        }
        server.shutdown();
    }

    /// Shutdown-drain ordering: every request accepted before `shutdown`
    /// gets a real response — `shutdown` blocks until the queue is
    /// drained, so no in-flight request is dropped on the floor.
    #[test]
    fn shutdown_drains_every_inflight_request() {
        struct SlowDoubler;
        impl BatchExecutor for SlowDoubler {
            fn max_batch(&self) -> usize {
                4
            }
            fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
                std::thread::sleep(Duration::from_millis(3));
                Ok(inputs.iter().map(|v| v.iter().map(|x| x * 2.0).collect()).collect())
            }
        }
        let server = InferenceServer::start(
            vec![Box::new(|| Box::new(SlowDoubler) as Box<dyn BatchExecutor>)],
            BatcherConfig { batch_size: 4, batch_timeout: Duration::from_millis(1) },
            64,
        );
        let handles: Vec<_> =
            (0..24).map(|i| server.submit_blocking(vec![i as f32]).unwrap()).collect();
        server.shutdown();
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(
                h.wait().unwrap(),
                vec![2.0 * i as f32],
                "request {i} was lost during shutdown"
            );
        }
    }

    /// Dropping the server while the bounded queue is under backpressure
    /// must not deadlock, and every *accepted* request still resolves
    /// (drained response or a clean `Closed`).
    #[test]
    fn drop_under_backpressure_neither_deadlocks_nor_loses_responses() {
        struct Slow;
        impl BatchExecutor for Slow {
            fn max_batch(&self) -> usize {
                1
            }
            fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
                std::thread::sleep(Duration::from_millis(20));
                Ok(inputs.to_vec())
            }
        }
        let server = InferenceServer::start(
            vec![Box::new(|| Box::new(Slow) as Box<dyn BatchExecutor>)],
            BatcherConfig { batch_size: 1, batch_timeout: Duration::from_millis(0) },
            2,
        );
        let mut handles = Vec::new();
        for i in 0..32 {
            match server.submit(vec![i as f32]) {
                Ok(h) => handles.push(h),
                Err(ServerError::Backpressure) => break,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(!handles.is_empty(), "at least one request must be accepted");
        drop(server); // implicit shutdown: must join, not hang
        for h in handles {
            match h.wait() {
                Ok(_) | Err(ServerError::Closed) => {}
                Err(e) => panic!("unexpected response after drop: {e}"),
            }
        }
    }

    #[test]
    fn shutdown_then_submit_fails() {
        let server = InferenceServer::start(vec![Box::new(|| Box::new(Doubler) as Box<dyn BatchExecutor>)], cfg(), 8);
        let m = server.metrics();
        assert_eq!(m.requests, 0);
        server.shutdown();
        // Server is consumed by shutdown; nothing further to assert —
        // compile-time ownership prevents use-after-shutdown.
    }
}
