//! The inference server: bounded submission queue (backpressure), a
//! collector thread forming batches, and a worker pool executing them.

use super::batcher::{BatchExecutor, Batcher, BatcherConfig, PendingRequest};
use super::metrics::MetricsRegistry;
use super::protocol;
// Mutex and the closing flag come from the crate-wide sync shim so loom
// builds model the worker handoff; Arc and mpsc stay `std` deliberately
// (see `crate::sync` module docs).
use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::Mutex;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Submission/response errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServerError {
    /// The bounded queue is full — caller should back off and retry.
    Backpressure,
    /// Server shutting down.
    Closed,
    /// Model execution failed.
    Exec(String),
    /// The request frame failed to decode (version, checksum,
    /// structure). The frame fails alone — no session or batch-mate is
    /// touched — and retrying the identical bytes cannot succeed.
    Protocol(String),
    /// `wait_timeout` elapsed before the response arrived.
    Timeout,
}

impl std::fmt::Display for ServerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServerError::Backpressure => write!(f, "queue full (backpressure)"),
            ServerError::Closed => write!(f, "server closed"),
            ServerError::Exec(e) => write!(f, "execution failed: {e}"),
            ServerError::Protocol(e) => write!(f, "protocol error: {e}"),
            ServerError::Timeout => write!(f, "timed out waiting for the response"),
        }
    }
}

/// Map an executor error string onto the typed boundary: the
/// [`protocol::ERR_PROTOCOL_PREFIX`] convention carries decode failures
/// across the string-typed response channel.
fn map_exec_error(e: String) -> ServerError {
    match e.strip_prefix(protocol::ERR_PROTOCOL_PREFIX) {
        Some(rest) => ServerError::Protocol(rest.to_string()),
        None => ServerError::Exec(e),
    }
}

impl std::error::Error for ServerError {}

/// The server boundary for the FTFI stack: typed integration errors
/// become execution failures on the response path — a malformed request
/// fails its own response without taking a worker thread down.
impl From<crate::ftfi::FtfiError> for ServerError {
    fn from(e: crate::ftfi::FtfiError) -> Self {
        ServerError::Exec(e.to_string())
    }
}

/// A running inference server. Dropping it (or calling
/// [`InferenceServer::shutdown`]) drains the queue and joins the threads.
pub struct InferenceServer {
    submit_tx: mpsc::SyncSender<PendingRequest>,
    metrics: Arc<MetricsRegistry>,
    collector: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    closing: Arc<AtomicBool>,
}

impl InferenceServer {
    /// Start with one execution thread per factory. Each worker *builds*
    /// its executor inside its own thread (PJRT clients/executables are
    /// not `Send` — they hold `Rc` internals — so construction must
    /// happen thread-locally) and round-robins over a shared batch
    /// channel.
    pub fn start(
        factories: Vec<Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>>,
        cfg: BatcherConfig,
        queue_capacity: usize,
    ) -> Self {
        Self::start_with_metrics(factories, cfg, queue_capacity, Arc::new(MetricsRegistry::new()))
    }

    /// Like [`InferenceServer::start`] but with a caller-provided
    /// metrics registry, so stateful executors (the streaming session
    /// table) can record evictions and decode failures into the same
    /// snapshot the server reports.
    pub fn start_with_metrics(
        factories: Vec<Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>>,
        cfg: BatcherConfig,
        queue_capacity: usize,
        metrics: Arc<MetricsRegistry>,
    ) -> Self {
        // lint: allow(unchecked-panic) — a documented construction
        // precondition: a server with zero workers can never serve, and
        // failing at startup (not at first submit) is the useful spot.
        assert!(!factories.is_empty());
        let (submit_tx, submit_rx) = mpsc::sync_channel::<PendingRequest>(queue_capacity);
        let (batch_tx, batch_rx) = mpsc::channel::<Vec<PendingRequest>>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let closing = Arc::new(AtomicBool::new(false));

        // Collector: requests → batches.
        let collector_cfg = cfg.clone();
        let collector = std::thread::Builder::new()
            .name("ftfi-collector".into())
            .spawn(move || {
                let batcher = Batcher::new(collector_cfg);
                while let Some(batch) = batcher.next_batch(&submit_rx) {
                    if batch_tx.send(batch).is_err() {
                        break;
                    }
                }
            })
            // lint: allow(unchecked-panic) — OS thread-spawn failure at
            // server startup is unrecoverable for the caller anyway.
            .expect("spawn collector");

        // Workers: batches → responses.
        let workers = factories
            .into_iter()
            .enumerate()
            .map(|(i, factory)| {
                let rx = Arc::clone(&batch_rx);
                let m = Arc::clone(&metrics);
                let cfg = cfg.clone();
                std::thread::Builder::new()
                    .name(format!("ftfi-worker-{i}"))
                    .spawn(move || {
                        let exec = factory();
                        let batcher = Batcher::new(cfg);
                        loop {
                            // A poisoned receiver lock means a sibling
                            // worker died mid-recv; exit cleanly instead
                            // of cascading the panic through the pool.
                            let batch = match rx.lock() {
                                Ok(guard) => guard.recv(),
                                Err(_) => break,
                            };
                            match batch {
                                Ok(b) => batcher.dispatch(b, exec.as_ref(), &m),
                                Err(_) => break,
                            }
                        }
                    })
                    // lint: allow(unchecked-panic) — OS thread-spawn
                    // failure at server startup is unrecoverable.
                    .expect("spawn worker")
            })
            .collect();

        InferenceServer { submit_tx, metrics, collector: Some(collector), workers, closing }
    }

    /// Submit one request; returns a handle to await the response.
    pub fn submit(&self, input: Vec<f32>) -> Result<ResponseHandle, ServerError> {
        if self.closing.load(Ordering::Relaxed) {
            return Err(ServerError::Closed);
        }
        let (tx, rx) = mpsc::channel();
        let req = PendingRequest { input, respond: tx, enqueued_at: Instant::now() };
        match self.submit_tx.try_send(req) {
            Ok(()) => {
                self.metrics.queue_enter();
                Ok(ResponseHandle { rx })
            }
            Err(mpsc::TrySendError::Full(_)) => Err(ServerError::Backpressure),
            Err(mpsc::TrySendError::Disconnected(_)) => Err(ServerError::Closed),
        }
    }

    /// Blocking submit: waits under backpressure instead of failing.
    pub fn submit_blocking(&self, input: Vec<f32>) -> Result<ResponseHandle, ServerError> {
        let (tx, rx) = mpsc::channel();
        let req = PendingRequest { input, respond: tx, enqueued_at: Instant::now() };
        self.submit_tx.send(req).map_err(|_| ServerError::Closed)?;
        self.metrics.queue_enter();
        Ok(ResponseHandle { rx })
    }

    /// Current metrics snapshot.
    pub fn metrics(&self) -> super::metrics::MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// The live metrics registry — shared with front-ends (the TCP
    /// acceptor) and stateful executors.
    pub fn registry(&self) -> Arc<MetricsRegistry> {
        Arc::clone(&self.metrics)
    }

    /// Graceful shutdown: stop accepting, drain, join.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.closing.store(true, Ordering::Relaxed);
        // Replace the sender so the collector's recv unblocks once all
        // outstanding handles are gone.
        let (dummy_tx, _) = mpsc::sync_channel(1);
        let old = std::mem::replace(&mut self.submit_tx, dummy_tx);
        drop(old);
        if let Some(c) = self.collector.take() {
            let _ = c.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        if self.collector.is_some() {
            self.shutdown_inner();
        }
    }
}

/// Await handle for one submitted request.
pub struct ResponseHandle {
    rx: mpsc::Receiver<Result<Vec<f32>, String>>,
}

impl ResponseHandle {
    /// Block until the response arrives.
    pub fn wait(self) -> Result<Vec<f32>, ServerError> {
        match self.rx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(e)) => Err(map_exec_error(e)),
            Err(_) => Err(ServerError::Closed),
        }
    }

    /// Block until the response arrives or `timeout` elapses. The chaos
    /// harness leans on this: a lost response fails the test with
    /// [`ServerError::Timeout`] instead of hanging it forever.
    pub fn wait_timeout(self, timeout: Duration) -> Result<Vec<f32>, ServerError> {
        match self.rx.recv_timeout(timeout) {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(e)) => Err(map_exec_error(e)),
            Err(mpsc::RecvTimeoutError::Timeout) => Err(ServerError::Timeout),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(ServerError::Closed),
        }
    }
}

// ---------------------------------------------------------------------
// TCP front-end
// ---------------------------------------------------------------------

/// Retry hint carried on `Rejected` responses: long enough to let one
/// batch window drain, short enough that backoff stays responsive.
const RETRY_HINT_MS: u32 = 5;

/// A TCP acceptor serving the typed wire protocol over real sockets:
/// `[u32 len][payload]` frames in, one response frame per request out,
/// in request order per connection. Admission failures become typed
/// `Rejected` frames; undecodable frames become typed `Error` frames
/// carrying the decode failure — the connection survives both.
///
/// Response-path faults (drop/duplicate, from an attached
/// [`super::faults::Faults`]) are applied at the writer, which is
/// exactly where a lossy network would apply them — the client's
/// req-id ledger is what detects and explains them.
pub struct TcpFront {
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<std::sync::Mutex<Vec<std::net::TcpStream>>>,
    handlers: Arc<std::sync::Mutex<Vec<JoinHandle<()>>>>,
}

impl TcpFront {
    /// Bind `bind` (e.g. `"127.0.0.1:0"`) and start accepting. The
    /// front holds its own `Arc` to the server; shut the front down
    /// before the server so in-flight requests drain.
    pub fn start(
        server: Arc<InferenceServer>,
        faults: Option<Arc<super::faults::Faults>>,
        bind: &str,
    ) -> std::io::Result<TcpFront> {
        let listener = std::net::TcpListener::bind(bind)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<std::sync::Mutex<Vec<std::net::TcpStream>>> = Arc::default();
        let handlers: Arc<std::sync::Mutex<Vec<JoinHandle<()>>>> = Arc::default();

        let accept_stop = Arc::clone(&stop);
        let accept_conns = Arc::clone(&conns);
        let accept_handlers = Arc::clone(&handlers);
        let accept = std::thread::Builder::new()
            .name("ftfi-tcp-accept".into())
            .spawn(move || loop {
                if accept_stop.load(Ordering::Relaxed) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let _ = stream.set_nonblocking(false);
                        let _ = stream.set_nodelay(true);
                        if let Ok(clone) = stream.try_clone() {
                            if let Ok(mut guard) = accept_conns.lock() {
                                guard.push(clone);
                            }
                        }
                        let server = Arc::clone(&server);
                        let faults = faults.clone();
                        let spawned = std::thread::Builder::new()
                            .name("ftfi-tcp-conn".into())
                            .spawn(move || serve_connection(&server, faults.as_deref(), stream));
                        if let (Ok(handle), Ok(mut guard)) = (spawned, accept_handlers.lock()) {
                            guard.push(handle);
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(_) => break,
                }
            })
            // lint: allow(unchecked-panic) — OS thread-spawn failure at
            // front-end startup is unrecoverable for the caller anyway.
            .expect("spawn tcp acceptor");

        Ok(TcpFront { local_addr, stop, accept: Some(accept), conns, handlers })
    }

    /// The bound address (useful with a `:0` bind).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// Stop accepting, tear live connections down and join all threads.
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Ok(mut guard) = self.conns.lock() {
            for conn in guard.drain(..) {
                let _ = conn.shutdown(std::net::Shutdown::Both);
            }
        }
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        if let Ok(mut guard) = self.handlers.lock() {
            for h in guard.drain(..) {
                let _ = h.join();
            }
        }
    }
}

impl Drop for TcpFront {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.stop_inner();
        }
    }
}

/// One connection's serve loop: frames are handled serially, so every
/// request on the connection gets exactly one response, in order —
/// clients open more connections for concurrency (loadgen does).
fn serve_connection(
    server: &InferenceServer,
    faults: Option<&super::faults::Faults>,
    stream: std::net::TcpStream,
) {
    let metrics = server.registry();
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = std::io::BufReader::new(reader_stream);
    let mut writer = std::io::BufWriter::new(stream);
    loop {
        let mut payload = match protocol::read_frame(&mut reader) {
            Ok(Some(p)) => p,
            // Clean EOF or a torn stream: either way the connection is
            // over; already-answered requests are unaffected.
            Ok(None) | Err(_) => return,
        };
        if let Some(f) = faults {
            f.corrupt_payload(&mut payload);
        }
        let req_id = protocol::peek_req_id(&payload).unwrap_or(0);
        let response = match protocol::decode_request(&payload) {
            Err(e) => {
                // Undecodable frames fail alone, without consuming a
                // queue slot — the typed Error echoes the peeked id.
                metrics.record_protocol_error();
                protocol::StreamResponse::Error {
                    message: format!("{}{e}", protocol::ERR_PROTOCOL_PREFIX),
                }
            }
            Ok(_) => match server.submit(protocol::payload_to_words(&payload)) {
                Err(ServerError::Backpressure) => protocol::StreamResponse::Rejected {
                    reason: protocol::RejectReason::Backpressure,
                    retry_after_hint_ms: RETRY_HINT_MS,
                },
                Err(e) => protocol::StreamResponse::Error { message: e.to_string() },
                Ok(handle) => match handle.wait() {
                    Ok(words) => match protocol::words_to_payload(&words) {
                        Ok(resp_payload) => {
                            if write_response(&mut writer, &resp_payload, faults).is_err() {
                                return;
                            }
                            continue;
                        }
                        Err(e) => protocol::StreamResponse::Error {
                            message: format!("{}{e}", protocol::ERR_PROTOCOL_PREFIX),
                        },
                    },
                    Err(ServerError::Exec(e))
                        if e.starts_with(protocol::ERR_SHED_PREFIX) =>
                    {
                        protocol::StreamResponse::Rejected {
                            reason: protocol::RejectReason::DeadlineExceeded,
                            retry_after_hint_ms: RETRY_HINT_MS,
                        }
                    }
                    Err(e) => protocol::StreamResponse::Error { message: e.to_string() },
                },
            },
        };
        let resp_payload = protocol::encode_response(&response, req_id);
        if write_response(&mut writer, &resp_payload, faults).is_err() {
            return;
        }
    }
}

/// Write one response frame, applying writer-side response faults
/// (silent drop, duplication) when an injector is attached.
fn write_response<W: std::io::Write>(
    w: &mut W,
    payload: &[u8],
    faults: Option<&super::faults::Faults>,
) -> std::io::Result<()> {
    if let Some(f) = faults {
        if f.take_drop_response() {
            return Ok(());
        }
        protocol::write_frame(w, payload)?;
        if f.take_duplicate_response() {
            protocol::write_frame(w, payload)?;
        }
        return Ok(());
    }
    protocol::write_frame(w, payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    struct Doubler;
    impl BatchExecutor for Doubler {
        fn max_batch(&self) -> usize {
            8
        }
        fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
            Ok(inputs.iter().map(|v| v.iter().map(|x| x * 2.0).collect()).collect())
        }
    }

    fn cfg() -> BatcherConfig {
        BatcherConfig { batch_size: 4, batch_timeout: Duration::from_millis(1), shed_after: None }
    }

    #[test]
    fn ftfi_error_converts_to_exec() {
        let e: ServerError = crate::ftfi::FtfiError::DisconnectedGraph.into();
        match e {
            ServerError::Exec(msg) => assert!(msg.contains("disconnected"), "{msg}"),
            other => panic!("expected Exec, got {other:?}"),
        }
    }

    #[test]
    fn end_to_end_roundtrip() {
        let server = InferenceServer::start(vec![Box::new(|| Box::new(Doubler) as Box<dyn BatchExecutor>)], cfg(), 64);
        let handles: Vec<_> =
            (0..20).map(|i| server.submit_blocking(vec![i as f32]).unwrap()).collect();
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.wait().unwrap(), vec![2.0 * i as f32]);
        }
        let m = server.metrics();
        assert_eq!(m.requests, 20);
        assert!(m.batches <= 20);
        server.shutdown();
    }

    #[test]
    fn multiple_workers() {
        let server = InferenceServer::start(
            vec![
                Box::new(|| Box::new(Doubler) as Box<dyn BatchExecutor>),
                Box::new(|| Box::new(Doubler) as Box<dyn BatchExecutor>),
            ],
            cfg(),
            64,
        );
        let handles: Vec<_> =
            (0..50).map(|i| server.submit_blocking(vec![i as f32]).unwrap()).collect();
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.wait().unwrap(), vec![2.0 * i as f32]);
        }
        server.shutdown();
    }

    #[test]
    fn backpressure_on_full_queue() {
        struct Slow;
        impl BatchExecutor for Slow {
            fn max_batch(&self) -> usize {
                1
            }
            fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
                std::thread::sleep(Duration::from_millis(30));
                Ok(inputs.to_vec())
            }
        }
        let server = InferenceServer::start(
            vec![Box::new(|| Box::new(Slow) as Box<dyn BatchExecutor>)],
            BatcherConfig {
                batch_size: 1,
                batch_timeout: Duration::from_millis(0),
                shed_after: None,
            },
            2,
        );
        // Flood: some submissions must hit Backpressure.
        let mut saw_backpressure = false;
        let mut handles = Vec::new();
        for i in 0..32 {
            match server.submit(vec![i as f32]) {
                Ok(h) => handles.push(h),
                Err(ServerError::Backpressure) => {
                    saw_backpressure = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(saw_backpressure, "queue never filled");
        for h in handles {
            let _ = h.wait();
        }
        server.shutdown();
    }

    /// Shutdown-drain ordering: every request accepted before `shutdown`
    /// gets a real response — `shutdown` blocks until the queue is
    /// drained, so no in-flight request is dropped on the floor.
    #[test]
    fn shutdown_drains_every_inflight_request() {
        struct SlowDoubler;
        impl BatchExecutor for SlowDoubler {
            fn max_batch(&self) -> usize {
                4
            }
            fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
                std::thread::sleep(Duration::from_millis(3));
                Ok(inputs.iter().map(|v| v.iter().map(|x| x * 2.0).collect()).collect())
            }
        }
        let server = InferenceServer::start(
            vec![Box::new(|| Box::new(SlowDoubler) as Box<dyn BatchExecutor>)],
            BatcherConfig {
                batch_size: 4,
                batch_timeout: Duration::from_millis(1),
                shed_after: None,
            },
            64,
        );
        let handles: Vec<_> =
            (0..24).map(|i| server.submit_blocking(vec![i as f32]).unwrap()).collect();
        server.shutdown();
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(
                h.wait().unwrap(),
                vec![2.0 * i as f32],
                "request {i} was lost during shutdown"
            );
        }
    }

    /// Dropping the server while the bounded queue is under backpressure
    /// must not deadlock, and every *accepted* request still resolves
    /// (drained response or a clean `Closed`).
    #[test]
    fn drop_under_backpressure_neither_deadlocks_nor_loses_responses() {
        struct Slow;
        impl BatchExecutor for Slow {
            fn max_batch(&self) -> usize {
                1
            }
            fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
                std::thread::sleep(Duration::from_millis(20));
                Ok(inputs.to_vec())
            }
        }
        let server = InferenceServer::start(
            vec![Box::new(|| Box::new(Slow) as Box<dyn BatchExecutor>)],
            BatcherConfig {
                batch_size: 1,
                batch_timeout: Duration::from_millis(0),
                shed_after: None,
            },
            2,
        );
        let mut handles = Vec::new();
        for i in 0..32 {
            match server.submit(vec![i as f32]) {
                Ok(h) => handles.push(h),
                Err(ServerError::Backpressure) => break,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(!handles.is_empty(), "at least one request must be accepted");
        drop(server); // implicit shutdown: must join, not hang
        for h in handles {
            match h.wait() {
                Ok(_) | Err(ServerError::Closed) => {}
                Err(e) => panic!("unexpected response after drop: {e}"),
            }
        }
    }

    #[test]
    fn exec_error_prefixes_map_to_typed_variants() {
        match map_exec_error(format!("{}bad checksum", protocol::ERR_PROTOCOL_PREFIX)) {
            ServerError::Protocol(m) => assert_eq!(m, "bad checksum"),
            other => panic!("expected Protocol, got {other:?}"),
        }
        match map_exec_error("plain failure".to_string()) {
            ServerError::Exec(m) => assert_eq!(m, "plain failure"),
            other => panic!("expected Exec, got {other:?}"),
        }
    }

    #[test]
    fn wait_timeout_reports_lost_responses() {
        struct Stall;
        impl BatchExecutor for Stall {
            fn max_batch(&self) -> usize {
                1
            }
            fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
                std::thread::sleep(Duration::from_millis(200));
                Ok(inputs.to_vec())
            }
        }
        let server = InferenceServer::start(
            vec![Box::new(|| Box::new(Stall) as Box<dyn BatchExecutor>)],
            cfg(),
            8,
        );
        let h = server.submit(vec![1.0]).unwrap();
        assert_eq!(
            h.wait_timeout(Duration::from_millis(5)).unwrap_err(),
            ServerError::Timeout
        );
        // A response that does arrive in time comes back intact.
        let h2 = server.submit(vec![2.0]).unwrap();
        assert_eq!(h2.wait_timeout(Duration::from_secs(10)).unwrap(), vec![2.0]);
        server.shutdown();
    }

    #[test]
    fn queue_depth_gauge_returns_to_zero_after_drain() {
        let server = InferenceServer::start(vec![Box::new(|| Box::new(Doubler) as Box<dyn BatchExecutor>)], cfg(), 64);
        let handles: Vec<_> =
            (0..10).map(|i| server.submit_blocking(vec![i as f32]).unwrap()).collect();
        for h in handles {
            h.wait().unwrap();
        }
        assert_eq!(server.metrics().queue_depth, 0, "drained queue must gauge zero");
        server.shutdown();
    }

    /// An executor that speaks the typed wire: decodes each request and
    /// answers with a deterministic `Output` frame.
    struct TypedEcho;
    impl BatchExecutor for TypedEcho {
        fn max_batch(&self) -> usize {
            4
        }
        fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
            Ok(inputs
                .iter()
                .map(|words| {
                    let payload = protocol::words_to_payload(words).expect("typed words");
                    let (id, req) = protocol::decode_request(&payload).expect("typed request");
                    let resp = protocol::StreamResponse::Output {
                        session: req.session(),
                        rows: 1,
                        channels: 1,
                        values: vec![req.session() as f32 + 0.5],
                    };
                    protocol::payload_to_words(&protocol::encode_response(&resp, id))
                })
                .collect())
        }
    }

    #[test]
    fn tcp_front_serves_typed_frames_and_survives_corrupt_ones() {
        let server = Arc::new(InferenceServer::start(
            vec![Box::new(|| Box::new(TypedEcho) as Box<dyn BatchExecutor>)],
            cfg(),
            64,
        ));
        let front = TcpFront::start(Arc::clone(&server), None, "127.0.0.1:0").unwrap();
        let mut conn = std::net::TcpStream::connect(front.local_addr()).unwrap();
        let mut rd = std::io::BufReader::new(conn.try_clone().unwrap());

        // A well-formed request round-trips with its id echoed.
        let req = protocol::StreamRequest::Lease { session: 6 };
        protocol::write_frame(&mut conn, &protocol::encode_request(&req, 71)).unwrap();
        let payload = protocol::read_frame(&mut rd).unwrap().expect("response frame");
        let (id, resp) = protocol::decode_response(&payload).unwrap();
        assert_eq!(id, 71);
        assert_eq!(
            resp,
            protocol::StreamResponse::Output {
                session: 6,
                rows: 1,
                channels: 1,
                values: vec![6.5],
            }
        );

        // A corrupted frame gets a typed protocol Error — and the
        // connection stays usable for the next request.
        let mut bad = protocol::encode_request(&req, 72);
        let last = bad.len() - 1;
        bad[last] ^= 0x10;
        protocol::write_frame(&mut conn, &bad).unwrap();
        let payload = protocol::read_frame(&mut rd).unwrap().expect("error frame");
        let (id, resp) = protocol::decode_response(&payload).unwrap();
        assert_eq!(id, 72, "the peeked req id must survive body corruption");
        match resp {
            protocol::StreamResponse::Error { message } => {
                assert!(
                    message.starts_with(protocol::ERR_PROTOCOL_PREFIX),
                    "got: {message}"
                );
            }
            other => panic!("expected Error, got {other:?}"),
        }
        assert_eq!(server.metrics().protocol_errors, 1);

        protocol::write_frame(&mut conn, &protocol::encode_request(&req, 73)).unwrap();
        let payload = protocol::read_frame(&mut rd).unwrap().expect("post-corruption frame");
        assert_eq!(protocol::decode_response(&payload).unwrap().0, 73);

        drop(conn);
        drop(rd);
        front.stop();
    }

    #[test]
    fn shutdown_then_submit_fails() {
        let server = InferenceServer::start(vec![Box::new(|| Box::new(Doubler) as Box<dyn BatchExecutor>)], cfg(), 8);
        let m = server.metrics();
        assert_eq!(m.requests, 0);
        server.shutdown();
        // Server is consumed by shutdown; nothing further to assert —
        // compile-time ownership prevents use-after-shutdown.
    }
}
