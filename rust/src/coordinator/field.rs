//! Field-integration serving: a [`BatchExecutor`] that answers
//! `Σ_u f(dist(v,u))·x[u]` requests over a fixed metric, plugging the
//! FTFI stack into the coordinator's queue/batcher/worker machinery.
//!
//! Two flavours:
//!
//! - [`FieldExecutor`] runs any [`FieldIntegrator`] backend (tree,
//!   MST-of-graph, brute reference) — one planning pass per request.
//! - [`PreparedFieldExecutor`] owns a [`TreeFieldIntegrator`] plus the
//!   [`PreparedPlans`] for one `f`, so every request reuses the frozen
//!   cross-block plans — the "build once, integrate any number of
//!   fields" serving pattern of §3.1/§3.2.
//!
//! Error contract: every [`FtfiError`] (shape mismatches above all) is
//! stringified into a per-request `Err(String)` via
//! [`BatchExecutor::execute_each`], which the batcher delivers as
//! `ServerError::Exec` to that request alone — a malformed request
//! fails its own response without poisoning its batch-mates, and can
//! never panic a worker thread.
//!
//! Both executors fan fused batches out across a [`WorkPool`] — the
//! serving batch axis — so one worker drives all cores of its budget.
//! Responses keep their request order and stay bit-identical to serial
//! execution (the pool's determinism contract). Share one pool across
//! workers (builder `.pool(..)` / [`FieldExecutor::with_pool`]) to bound
//! the process-wide thread count.

use super::batcher::BatchExecutor;
use crate::ftfi::functions::FDist;
use crate::ftfi::{FieldIntegrator, FtfiError, TreeFieldIntegrator};
use crate::linalg::matrix::Matrix;
use crate::runtime::pool::{WorkPool, PAR_MAP_MIN_N};
use crate::tree::integrator_tree::PreparedPlans;
use std::sync::Arc;

/// Decode one flattened request into an `n×d` field (row-major, rows
/// indexed by vertex id). The request length must be a non-zero
/// multiple of `n`.
fn decode(input: &[f32], n: usize) -> Result<Matrix, FtfiError> {
    if input.is_empty() || n == 0 || input.len() % n != 0 {
        return Err(FtfiError::ShapeMismatch { expected: n, got: input.len() });
    }
    let d = input.len() / n;
    Ok(Matrix::from_vec(n, d, input.iter().map(|&v| v as f64).collect()))
}

fn encode(m: Matrix) -> Vec<f32> {
    m.data().iter().map(|&v| v as f32).collect()
}

/// Serve integrations of a fixed `f` through any [`FieldIntegrator`]
/// backend. `I: Sync` because fused batches fan out across the pool's
/// threads (every integrator in this crate is `Sync`).
pub struct FieldExecutor<I: FieldIntegrator + Sync + 'static> {
    integrator: I,
    f: FDist,
    max_batch: usize,
    pool: Arc<WorkPool>,
}

impl<I: FieldIntegrator + Sync + 'static> FieldExecutor<I> {
    /// Build reusing the integrator's own work pool when it has one
    /// (so the batch fan-out and the integrator's internal forks share
    /// one thread budget), else an auto-sized pool (`FTFI_THREADS`,
    /// else all cores).
    pub fn new(integrator: I, f: FDist, max_batch: usize) -> Self {
        let pool = integrator
            .work_pool()
            .cloned()
            .unwrap_or_else(|| Arc::new(WorkPool::with_auto(0)));
        Self::with_pool(integrator, f, max_batch, pool)
    }

    /// Build over a shared work pool (bounds the process-wide thread
    /// budget when several workers serve side by side).
    pub fn with_pool(integrator: I, f: FDist, max_batch: usize, pool: Arc<WorkPool>) -> Self {
        FieldExecutor { integrator, f, max_batch: max_batch.max(1), pool }
    }

    fn run_one(&self, input: &[f32]) -> Result<Vec<f32>, String> {
        let x = decode(input, self.integrator.n()).map_err(|e| e.to_string())?;
        let out = self.integrator.integrate(&self.f, &x).map_err(|e| e.to_string())?;
        Ok(encode(out))
    }
}

impl<I: FieldIntegrator + Sync + 'static> BatchExecutor for FieldExecutor<I> {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        self.execute_each(inputs).into_iter().collect()
    }

    /// Requests fail independently: a malformed request gets its own
    /// `Err` while its batch-mates still succeed. Requests fan out
    /// across the work pool (unless the metric is too small to justify
    /// helper threads); responses keep the request order.
    fn execute_each(&self, inputs: &[Vec<f32>]) -> Vec<Result<Vec<f32>, String>> {
        if self.integrator.n() < PAR_MAP_MIN_N {
            return inputs.iter().map(|input| self.run_one(input)).collect();
        }
        self.pool.map(inputs, |_, input| self.run_one(input))
    }
}

/// Serve integrations of a fixed `f` with prepared plans: the Chebyshev
/// expansions / lattice FFT tables / separable decompositions are built
/// once at construction and reused for every request.
pub struct PreparedFieldExecutor {
    tfi: TreeFieldIntegrator,
    plans: PreparedPlans,
    max_batch: usize,
}

impl PreparedFieldExecutor {
    /// Freeze `f` (with a `channels` width hint for the planner) into a
    /// serving executor. Fails with a typed [`FtfiError`] — e.g. a
    /// forced-but-inapplicable strategy in the integrator's policy —
    /// instead of panicking inside a worker thread later.
    pub fn new(
        tfi: TreeFieldIntegrator,
        f: &FDist,
        channels: usize,
        max_batch: usize,
    ) -> Result<Self, FtfiError> {
        let plans = tfi.prepare_plans(f, channels)?;
        Ok(PreparedFieldExecutor { tfi, plans, max_batch: max_batch.max(1) })
    }

    /// Number of vertices a request row must cover.
    pub fn n(&self) -> usize {
        self.tfi.n()
    }

    fn run_one(&self, input: &[f32]) -> Result<Vec<f32>, String> {
        let x = decode(input, self.tfi.n()).map_err(|e| e.to_string())?;
        let out = self.tfi.integrate_prepared(&x, &self.plans).map_err(|e| e.to_string())?;
        Ok(encode(out))
    }
}

impl BatchExecutor for PreparedFieldExecutor {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        self.execute_each(inputs).into_iter().collect()
    }

    /// Requests fail independently: a malformed request gets its own
    /// `Err` while its batch-mates still succeed. Requests fan out
    /// across the integrator's work pool (set per builder via
    /// `.threads(..)` / `.pool(..)`) unless the metric is too small to
    /// justify helper threads; responses keep the request order.
    fn execute_each(&self, inputs: &[Vec<f32>]) -> Vec<Result<Vec<f32>, String>> {
        if self.tfi.n() < PAR_MAP_MIN_N {
            return inputs.iter().map(|input| self.run_one(input)).collect();
        }
        self.tfi.pool().map(inputs, |_, input| self.run_one(input))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, InferenceServer, ServerError};
    use crate::ftfi::brute::btfi;
    use crate::graph::generators;
    use crate::ml::rng::Pcg;
    use std::time::Duration;

    #[test]
    fn prepared_executor_serves_correct_integrals() {
        let mut rng = Pcg::seed(1);
        let tree = generators::random_tree(40, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
        let exec = PreparedFieldExecutor::new(tfi, &f, 1, 8).unwrap();
        let x: Vec<f32> = (0..40).map(|i| (i as f32 * 0.1).sin()).collect();
        let out = exec.execute(&[x.clone()]).unwrap();
        let xm = Matrix::from_vec(40, 1, x.iter().map(|&v| v as f64).collect());
        let want = btfi(&tree, &f, &xm);
        for (got, w) in out[0].iter().zip(want.data()) {
            assert!((*got as f64 - w).abs() < 1e-4 * (1.0 + w.abs()), "{got} vs {w}");
        }
    }

    /// The executor's request loop runs on the workspace hot path
    /// (`integrate_prepared`): responses must stay bit-identical to the
    /// legacy per-node-allocation reference, and repeated requests must
    /// reuse the plan's workspaces without leaking state across them.
    #[test]
    fn prepared_executor_serves_the_workspace_hot_path() {
        let mut rng = Pcg::seed(7);
        let tree = generators::random_tree(120, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        let ref_tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        // Same tree → same IT shape, but plans are instance-pinned:
        // build the reference plans on the reference integrator.
        let ref_plans = ref_tfi.prepare_plans(&f, 1).unwrap();
        let exec = PreparedFieldExecutor::new(tfi, &f, 1, 8).unwrap();
        for k in 0..3 {
            let input: Vec<f32> = (0..120).map(|i| ((i + 31 * k) as f32 * 0.05).sin()).collect();
            let got = exec.run_one(&input).unwrap();
            let x = decode(&input, 120).unwrap();
            let want = encode(ref_tfi.integrate_prepared_legacy(&x, &ref_plans).unwrap());
            assert_eq!(got, want, "request {k}: served response must match the legacy path");
        }
    }

    #[test]
    fn malformed_request_maps_to_exec_error_without_killing_workers() {
        let mut rng = Pcg::seed(2);
        let tree = generators::random_tree(24, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let server = InferenceServer::start(
            vec![Box::new(move || {
                let tfi = TreeFieldIntegrator::builder(&tree).build().expect("valid tree");
                Box::new(PreparedFieldExecutor::new(tfi, &f, 1, 4).expect("plannable f"))
                    as Box<dyn BatchExecutor>
            })],
            BatcherConfig { batch_size: 1, batch_timeout: Duration::from_millis(1) },
            64,
        );
        // Wrong-length field: must come back as ServerError::Exec (the
        // FtfiError::ShapeMismatch string), not crash the worker.
        let bad = server.submit_blocking(vec![1.0f32; 7]).unwrap();
        match bad.wait() {
            Err(ServerError::Exec(msg)) => {
                assert!(msg.contains("shape mismatch"), "unexpected message: {msg}")
            }
            other => panic!("expected Exec error, got {other:?}"),
        }
        // The worker survived: a well-formed request still succeeds.
        let good = server.submit_blocking(vec![1.0f32; 24]).unwrap();
        let out = good.wait().expect("worker should still be alive");
        assert_eq!(out.len(), 24);
        server.shutdown();
    }

    #[test]
    fn malformed_request_fails_alone_inside_a_batch() {
        let mut rng = Pcg::seed(4);
        let tree = generators::random_tree(16, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.3, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
        let exec = PreparedFieldExecutor::new(tfi, &f, 1, 4).unwrap();
        let good = vec![1.0f32; 16];
        let bad = vec![1.0f32; 7];
        let results = exec.execute_each(&[good.clone(), bad, good]);
        assert_eq!(results.len(), 3);
        assert!(results[0].is_ok());
        match &results[1] {
            Err(e) => assert!(e.contains("shape mismatch"), "{e}"),
            Ok(_) => panic!("malformed request must fail"),
        }
        assert!(results[2].is_ok(), "batch-mates must not be poisoned");
    }

    #[test]
    fn parallel_execute_each_is_ordered_and_bit_identical_to_serial() {
        let mut rng = Pcg::seed(5);
        let tree = generators::random_tree(700, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
        let serial = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        let par = TreeFieldIntegrator::builder(&tree).threads(4).build().unwrap();
        let exec_s = PreparedFieldExecutor::new(serial, &f, 1, 8).unwrap();
        let exec_p = PreparedFieldExecutor::new(par, &f, 1, 8).unwrap();
        let inputs: Vec<Vec<f32>> = (0..6)
            .map(|k| (0..700).map(|i| ((i + 137 * k) as f32 * 0.01).sin()).collect())
            .collect();
        let a = exec_s.execute_each(&inputs);
        let b = exec_p.execute_each(&inputs);
        assert_eq!(a.len(), b.len());
        for (i, (ra, rb)) in a.iter().zip(&b).enumerate() {
            let (ra, rb) = (ra.as_ref().unwrap(), rb.as_ref().unwrap());
            assert_eq!(ra, rb, "request {i}: parallel response must be bit-identical");
        }
    }

    /// One thread budget end to end: the generic executor must reuse the
    /// integrator's pool rather than stacking a second auto-sized one.
    #[test]
    fn generic_executor_reuses_the_integrator_pool() {
        use crate::ftfi::GraphFieldIntegrator;
        let mut rng = Pcg::seed(6);
        let g = generators::path_plus_random_edges(20, 10, &mut rng);
        let gfi = GraphFieldIntegrator::builder(&g).threads(3).build().unwrap();
        let shared = Arc::clone(gfi.tree_integrator().pool());
        let exec = FieldExecutor::new(gfi, FDist::Identity, 4);
        assert!(Arc::ptr_eq(&exec.pool, &shared), "executor must reuse the integrator's pool");
        assert_eq!(exec.pool.threads(), 3);
    }

    #[test]
    fn generic_executor_works_over_any_backend() {
        use crate::ftfi::GraphFieldIntegrator;
        let mut rng = Pcg::seed(3);
        let g = generators::path_plus_random_edges(30, 15, &mut rng);
        let gfi = GraphFieldIntegrator::try_new(&g).unwrap();
        let exec = FieldExecutor::new(gfi, FDist::Identity, 4);
        let x = vec![1.0f32; 30];
        let out = exec.execute(&[x]).unwrap();
        assert_eq!(out[0].len(), 30);
        // Empty input is a shape error, not a panic.
        assert!(exec.execute(&[vec![]]).is_err());
    }

    /// Ensemble serving path: the generic executor over an
    /// [`EnsembleFieldIntegrator`] shares the ensemble's pool, fans
    /// batches out, and isolates per-request failures.
    #[test]
    fn ensemble_executor_batch_fanout_and_error_isolation() {
        use crate::ftfi::ensemble::EnsembleFieldIntegrator;
        let mut rng = Pcg::seed(21);
        let g = generators::path_plus_random_edges(30, 15, &mut rng);
        let ens = EnsembleFieldIntegrator::builder(&g).trees(3).seed(5).build().unwrap();
        let shared = Arc::clone(ens.pool());
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let exec = FieldExecutor::new(ens, f, 4);
        assert!(
            Arc::ptr_eq(&exec.pool, &shared),
            "executor must reuse the ensemble's pool (one thread budget)"
        );
        let good = vec![1.0f32; 30];
        let bad = vec![1.0f32; 7];
        let results = exec.execute_each(&[good.clone(), bad, good]);
        assert_eq!(results.len(), 3);
        assert!(results[0].is_ok());
        match &results[1] {
            Err(e) => assert!(e.contains("shape mismatch"), "{e}"),
            Ok(_) => panic!("malformed request must fail alone"),
        }
        assert!(results[2].is_ok(), "batch-mates must not be poisoned");
        assert_eq!(results[0].as_ref().unwrap(), results[2].as_ref().unwrap());
    }

    /// Ensemble serving path: fixed `(seed, trees)` responses are
    /// bit-identical across thread counts (the CI thread matrix runs
    /// the whole suite under `FTFI_THREADS ∈ {1, 4}`; the explicit
    /// `.threads(..)` knobs pin both engines regardless).
    #[test]
    fn ensemble_executor_is_seed_deterministic_across_thread_counts() {
        use crate::ftfi::ensemble::EnsembleFieldIntegrator;
        let mut rng = Pcg::seed(22);
        // n ≥ 256 so both the batch fan-out and the tree axis engage.
        let g = generators::path_plus_random_edges(300, 150, &mut rng);
        let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
        let build = |threads: usize| {
            let b = EnsembleFieldIntegrator::builder(&g).trees(3).seed(9).threads(threads);
            b.build().unwrap()
        };
        let exec_s = FieldExecutor::new(build(1), f.clone(), 8);
        let exec_p = FieldExecutor::new(build(4), f, 8);
        let inputs: Vec<Vec<f32>> = (0..4)
            .map(|k| (0..300).map(|i| ((i + 97 * k) as f32 * 0.01).sin()).collect())
            .collect();
        let a = exec_s.execute_each(&inputs);
        let b = exec_p.execute_each(&inputs);
        assert_eq!(a.len(), b.len());
        for (i, (ra, rb)) in a.iter().zip(&b).enumerate() {
            let (ra, rb) = (ra.as_ref().unwrap(), rb.as_ref().unwrap());
            assert_eq!(ra, rb, "request {i}: ensemble response must be bit-identical");
        }
    }
}
