//! Field-integration serving: a [`BatchExecutor`] that answers
//! `Σ_u f(dist(v,u))·x[u]` requests over a fixed metric, plugging the
//! FTFI stack into the coordinator's queue/batcher/worker machinery.
//!
//! Three flavours:
//!
//! - [`FieldExecutor`] runs any [`FieldIntegrator`] backend (tree,
//!   MST-of-graph, brute reference) — one planning pass per request.
//! - [`PreparedFieldExecutor`] owns a [`TreeFieldIntegrator`] plus the
//!   [`PreparedPlans`] for one `f`, so every request reuses the frozen
//!   cross-block plans — the "build once, integrate any number of
//!   fields" serving pattern of §3.1/§3.2.
//! - [`StreamingFieldExecutor`] serves the *online* workload: stateful
//!   per-session [`StreamingIntegrator`]s behind one shared tree / plan
//!   set, answering sparse `apply_update` requests through the delta
//!   fast path (wire protocol below) with per-update latency
//!   percentiles in the [`MetricsRegistry`].
//!
//! Error contract: every [`FtfiError`] (shape mismatches above all) is
//! stringified into a per-request `Err(String)` via
//! [`BatchExecutor::execute_each`], which the batcher delivers as
//! `ServerError::Exec` to that request alone — a malformed request
//! fails its own response without poisoning its batch-mates, and can
//! never panic a worker thread.
//!
//! Both executors fan fused batches out across a [`WorkPool`] — the
//! serving batch axis — so one worker drives all cores of its budget.
//! Responses keep their request order and stay bit-identical to serial
//! execution (the pool's determinism contract). Share one pool across
//! workers (builder `.pool(..)` / [`FieldExecutor::with_pool`]) to bound
//! the process-wide thread count.

use super::batcher::BatchExecutor;
use super::metrics::{MetricsRegistry, MetricsSnapshot};
use crate::ftfi::functions::FDist;
use crate::ftfi::streaming::{SharedPlans, StreamingIntegrator};
use crate::ftfi::{FieldIntegrator, FtfiError, TreeFieldIntegrator};
use crate::linalg::lanes::Precision;
use crate::linalg::matrix::Matrix;
use crate::runtime::pool::{WorkPool, PAR_MAP_MIN_N};
// Session locks come from the crate-wide sync shim so loom can model the
// set-vs-update race; Arc deliberately stays `std` (see `crate::sync`).
use crate::sync::Mutex;
use crate::tree::integrator_tree::PreparedPlans;
use std::sync::Arc;
use std::time::Instant;

/// Decode one flattened request into an `n×d` field (row-major, rows
/// indexed by vertex id). The request length must be a non-zero
/// multiple of `n`.
fn decode(input: &[f32], n: usize) -> Result<Matrix, FtfiError> {
    if input.is_empty() || n == 0 || input.len() % n != 0 {
        return Err(FtfiError::ShapeMismatch { expected: n, got: input.len() });
    }
    let d = input.len() / n;
    Ok(Matrix::from_vec(n, d, input.iter().map(|&v| v as f64).collect()))
}

fn encode(m: Matrix) -> Vec<f32> {
    m.data().iter().map(|&v| v as f32).collect()
}

/// Serve integrations of a fixed `f` through any [`FieldIntegrator`]
/// backend. `I: Sync` because fused batches fan out across the pool's
/// threads (every integrator in this crate is `Sync`).
pub struct FieldExecutor<I: FieldIntegrator + Sync + 'static> {
    integrator: I,
    f: FDist,
    max_batch: usize,
    pool: Arc<WorkPool>,
}

impl<I: FieldIntegrator + Sync + 'static> FieldExecutor<I> {
    /// Build reusing the integrator's own work pool when it has one
    /// (so the batch fan-out and the integrator's internal forks share
    /// one thread budget), else an auto-sized pool (`FTFI_THREADS`,
    /// else all cores).
    pub fn new(integrator: I, f: FDist, max_batch: usize) -> Self {
        let pool = integrator
            .work_pool()
            .cloned()
            .unwrap_or_else(|| Arc::new(WorkPool::with_auto(0)));
        Self::with_pool(integrator, f, max_batch, pool)
    }

    /// Build over a shared work pool (bounds the process-wide thread
    /// budget when several workers serve side by side).
    pub fn with_pool(integrator: I, f: FDist, max_batch: usize, pool: Arc<WorkPool>) -> Self {
        FieldExecutor { integrator, f, max_batch: max_batch.max(1), pool }
    }

    fn run_one(&self, input: &[f32]) -> Result<Vec<f32>, String> {
        let x = decode(input, self.integrator.n()).map_err(|e| e.to_string())?;
        let out = self.integrator.integrate(&self.f, &x).map_err(|e| e.to_string())?;
        Ok(encode(out))
    }
}

impl<I: FieldIntegrator + Sync + 'static> BatchExecutor for FieldExecutor<I> {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        self.execute_each(inputs).into_iter().collect()
    }

    /// Requests fail independently: a malformed request gets its own
    /// `Err` while its batch-mates still succeed. Requests fan out
    /// across the work pool (unless the metric is too small to justify
    /// helper threads); responses keep the request order.
    fn execute_each(&self, inputs: &[Vec<f32>]) -> Vec<Result<Vec<f32>, String>> {
        if self.integrator.n() < PAR_MAP_MIN_N {
            return inputs.iter().map(|input| self.run_one(input)).collect();
        }
        self.pool.map(inputs, |_, input| self.run_one(input))
    }
}

/// Serve integrations of a fixed `f` with prepared plans: the Chebyshev
/// expansions / lattice FFT tables / separable decompositions are built
/// once at construction and reused for every request.
pub struct PreparedFieldExecutor {
    tfi: TreeFieldIntegrator,
    plans: PreparedPlans,
    max_batch: usize,
}

impl PreparedFieldExecutor {
    /// Freeze `f` (with a `channels` width hint for the planner) into a
    /// serving executor. Fails with a typed [`FtfiError`] — e.g. a
    /// forced-but-inapplicable strategy in the integrator's policy —
    /// instead of panicking inside a worker thread later.
    pub fn new(
        tfi: TreeFieldIntegrator,
        f: &FDist,
        channels: usize,
        max_batch: usize,
    ) -> Result<Self, FtfiError> {
        let plans = tfi.prepare_plans(f, channels)?;
        Ok(PreparedFieldExecutor { tfi, plans, max_batch: max_batch.max(1) })
    }

    /// Number of vertices a request row must cover.
    pub fn n(&self) -> usize {
        self.tfi.n()
    }

    fn run_one(&self, input: &[f32]) -> Result<Vec<f32>, String> {
        let x = decode(input, self.tfi.n()).map_err(|e| e.to_string())?;
        let out = self.tfi.integrate_prepared(&x, &self.plans).map_err(|e| e.to_string())?;
        Ok(encode(out))
    }
}

impl BatchExecutor for PreparedFieldExecutor {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        self.execute_each(inputs).into_iter().collect()
    }

    /// Requests fail independently: a malformed request gets its own
    /// `Err` while its batch-mates still succeed. Requests fan out
    /// across the integrator's work pool (set per builder via
    /// `.threads(..)` / `.pool(..)`) unless the metric is too small to
    /// justify helper threads; responses keep the request order.
    fn execute_each(&self, inputs: &[Vec<f32>]) -> Vec<Result<Vec<f32>, String>> {
        if self.tfi.n() < PAR_MAP_MIN_N {
            return inputs.iter().map(|input| self.run_one(input)).collect();
        }
        self.tfi.pool().map(inputs, |_, input| self.run_one(input))
    }
}

/// Opcode of a streaming request (`input[0]`): install/overwrite a
/// session's full field.
pub const STREAM_OP_SET: f32 = 0.0;
/// Opcode of a streaming request (`input[0]`): sparse row update.
pub const STREAM_OP_UPDATE: f32 = 1.0;
/// Opcode of a streaming request (`input[0]`): reweight one tree edge
/// of the shared metric (every session sees the change).
pub const STREAM_OP_REPLAN: f32 = 2.0;

/// Parse a non-negative integral f32 below `limit` (session ids, row
/// counts and row indices on the f32 wire; integers are exact in f32 up
/// to 2²⁴, far above any supported `n`).
fn parse_index(v: f32, limit: usize, what: &str) -> Result<usize, String> {
    if !v.is_finite() || v < 0.0 || v.fract() != 0.0 || (v as usize) >= limit {
        return Err(format!("{what} {v} invalid (expected an integer in 0..{limit})"));
    }
    Ok(v as usize)
}

/// Serve the streaming/online workload: per-session
/// [`StreamingIntegrator`]s (bounded by `max_sessions`) sharing one
/// tree, one frozen plan set and one work pool. Requests ride the
/// coordinator's `Vec<f32>` wire:
///
/// ```text
/// set:    [0.0, session, field…]            field = n·d values, d = len/n
/// update: [1.0, session, k, row…, values…]  k rows then k·d values
/// replan: [2.0, session, u, v, w]           reweight tree edge {u, v}
/// ```
///
/// All three return the session's full `n·d` output. Updates run the
/// sparse delta fast path with the session's `refresh_every` drift
/// policy; replans reweight one edge of the *shared* metric in place
/// (the O(log n) in-place re-plan, see DESIGN.md "Dynamic graphs & edge
/// re-plans") — the issuing session's output is refreshed eagerly and
/// returned, sibling sessions refresh lazily on their next request. A
/// malformed request (unknown opcode/session, bad row, non-tree edge,
/// bad weight, shape mismatch) fails alone — the session keeps its
/// state, the shared plans stay untouched, and batch-mates keep their
/// responses. Sessions are `Mutex`-guarded, so concurrent batch fan-out
/// over *different* sessions parallelises while same-session updates
/// serialise (arrival order within one fused batch is unspecified —
/// clients that need ordering submit one in-flight update per session).
/// Lock ordering: the session mutex is always taken before the shared
/// plan lock (never the reverse), so update/replan interleavings cannot
/// deadlock.
pub struct StreamingFieldExecutor {
    shared: Arc<SharedPlans>,
    /// Cached from the integrator at construction (the integrator now
    /// lives inside the plan cell; these never change afterwards).
    n: usize,
    precision: Precision,
    pool: Arc<WorkPool>,
    refresh_every: usize,
    max_batch: usize,
    sessions: Vec<Mutex<Option<StreamingIntegrator>>>,
    metrics: Arc<MetricsRegistry>,
}

impl StreamingFieldExecutor {
    /// Freeze `f` (with a `channels` planner hint) and allocate
    /// `max_sessions` empty session slots. `refresh_every` is the drift
    /// policy every session is opened with (`0` = delta-only).
    pub fn new(
        tfi: TreeFieldIntegrator,
        f: &FDist,
        channels: usize,
        refresh_every: usize,
        max_sessions: usize,
        max_batch: usize,
    ) -> Result<Self, FtfiError> {
        let plans = tfi.prepare_plans(f, channels)?;
        let n = tfi.n();
        let precision = plans.precision();
        let pool = Arc::clone(tfi.pool());
        let sessions = (0..max_sessions.max(1)).map(|_| Mutex::new(None)).collect();
        Ok(StreamingFieldExecutor {
            shared: Arc::new(SharedPlans::new(tfi, plans)),
            n,
            precision,
            pool,
            refresh_every,
            max_batch: max_batch.max(1),
            sessions,
            metrics: Arc::new(MetricsRegistry::new()),
        })
    }

    /// Number of vertices a session field must cover.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Session slots.
    pub fn max_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// The serving tier inherited from the integrator at plan-freeze
    /// time (`TreeFieldIntegratorBuilder::precision`): every session's
    /// full integrations, delta updates and refreshes run this tier.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Update-latency percentiles and counters (the streaming SLO);
    /// share the registry with a dashboard via
    /// [`StreamingFieldExecutor::metrics_registry`].
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// The executor's metrics registry (update-latency histogram).
    pub fn metrics_registry(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    fn run_one(&self, input: &[f32]) -> Result<Vec<f32>, String> {
        if input.len() < 2 {
            return Err("streaming request needs [op, session, …]".to_string());
        }
        let sid = parse_index(input[1], self.sessions.len(), "session")?;
        if input[0] == STREAM_OP_SET {
            self.run_set(sid, &input[2..])
        } else if input[0] == STREAM_OP_UPDATE {
            let t0 = Instant::now();
            let out = self.run_update(sid, &input[2..])?;
            self.metrics.record_update_latency(t0.elapsed().as_secs_f64());
            Ok(out)
        } else if input[0] == STREAM_OP_REPLAN {
            self.run_replan(sid, &input[2..])
        } else {
            Err(format!("unknown streaming opcode {} (0 = set, 1 = update, 2 = replan)", input[0]))
        }
    }

    fn run_set(&self, sid: usize, payload: &[f32]) -> Result<Vec<f32>, String> {
        let n = self.n;
        if n == 0 || payload.is_empty() || payload.len() % n != 0 {
            return Err(FtfiError::ShapeMismatch { expected: n, got: payload.len() }.to_string());
        }
        let d = payload.len() / n;
        let field = Matrix::from_vec(n, d, payload.iter().map(|&v| v as f64).collect());
        let session =
            StreamingIntegrator::new(Arc::clone(&self.shared), field, self.refresh_every)
                .map_err(|e| e.to_string())?;
        let out = session.output().data().iter().map(|&v| v as f32).collect();
        // A poisoned slot means another request panicked mid-session;
        // fail this request instead of cascading the panic.
        let mut guard = self.sessions[sid]
            .lock()
            .map_err(|_| format!("session {sid} poisoned by an earlier panic"))?;
        *guard = Some(session);
        Ok(out)
    }

    /// `[u, v, w]` payload: reweight the tree edge `{u, v}` to `w`.
    /// The session mutex is taken *before* the shared plan lock (the
    /// crate-wide lock order); validation failures surface as this
    /// request's error with the plans and every session untouched.
    fn run_replan(&self, sid: usize, payload: &[f32]) -> Result<Vec<f32>, String> {
        if payload.len() != 3 {
            return Err(format!("replan needs [u, v, w], got {} values", payload.len()));
        }
        let u = parse_index(payload[0], self.n, "vertex")?;
        let v = parse_index(payload[1], self.n, "vertex")?;
        let w = payload[2] as f64;
        let mut guard = self.sessions[sid]
            .lock()
            .map_err(|_| format!("session {sid} poisoned by an earlier panic"))?;
        let session = guard
            .as_mut()
            .ok_or_else(|| format!("session {sid} not initialised (send a set request first)"))?;
        session.update_edge(u, v, w).map_err(|e| e.to_string())?;
        Ok(session.output().data().iter().map(|&v| v as f32).collect())
    }

    fn run_update(&self, sid: usize, payload: &[f32]) -> Result<Vec<f32>, String> {
        let n = self.n;
        if payload.is_empty() {
            return Err("update needs [k, rows…, values…]".to_string());
        }
        let k = parse_index(payload[0], n + 1, "row count")?;
        if payload.len() < 1 + k {
            return Err(format!("update lists {k} rows but carries {}", payload.len() - 1));
        }
        let mut rows = Vec::with_capacity(k);
        for &r in &payload[1..1 + k] {
            rows.push(parse_index(r, n, "row")? as u32);
        }
        let vals = &payload[1 + k..];
        let mut guard = self.sessions[sid]
            .lock()
            .map_err(|_| format!("session {sid} poisoned by an earlier panic"))?;
        let session = guard
            .as_mut()
            .ok_or_else(|| format!("session {sid} not initialised (send a set request first)"))?;
        let d = session.channels();
        if vals.len() != k * d {
            return Err(FtfiError::ShapeMismatch { expected: k * d, got: vals.len() }.to_string());
        }
        let values = Matrix::from_vec(k, d, vals.iter().map(|&v| v as f64).collect());
        let out = session.apply_update(&rows, &values).map_err(|e| e.to_string())?;
        Ok(out.data().iter().map(|&v| v as f32).collect())
    }
}

impl BatchExecutor for StreamingFieldExecutor {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        self.execute_each(inputs).into_iter().collect()
    }

    /// Requests fail independently and fan out across the integrator's
    /// pool; per-session mutexes serialise same-session updates while
    /// distinct sessions proceed in parallel.
    fn execute_each(&self, inputs: &[Vec<f32>]) -> Vec<Result<Vec<f32>, String>> {
        if self.n < PAR_MAP_MIN_N {
            return inputs.iter().map(|input| self.run_one(input)).collect();
        }
        self.pool.map(inputs, |_, input| self.run_one(input))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, InferenceServer, ServerError};
    use crate::ftfi::brute::btfi;
    use crate::graph::generators;
    use crate::ml::rng::Pcg;
    use std::time::Duration;

    #[test]
    fn prepared_executor_serves_correct_integrals() {
        let mut rng = Pcg::seed(1);
        let tree = generators::random_tree(40, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
        let exec = PreparedFieldExecutor::new(tfi, &f, 1, 8).unwrap();
        let x: Vec<f32> = (0..40).map(|i| (i as f32 * 0.1).sin()).collect();
        let out = exec.execute(&[x.clone()]).unwrap();
        let xm = Matrix::from_vec(40, 1, x.iter().map(|&v| v as f64).collect());
        let want = btfi(&tree, &f, &xm);
        for (got, w) in out[0].iter().zip(want.data()) {
            assert!((*got as f64 - w).abs() < 1e-4 * (1.0 + w.abs()), "{got} vs {w}");
        }
    }

    /// The executor's request loop runs on the workspace hot path
    /// (`integrate_prepared`): responses must stay bit-identical to the
    /// legacy per-node-allocation reference, and repeated requests must
    /// reuse the plan's workspaces without leaking state across them.
    #[test]
    fn prepared_executor_serves_the_workspace_hot_path() {
        let mut rng = Pcg::seed(7);
        let tree = generators::random_tree(120, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        let ref_tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        // Same tree → same IT shape, but plans are instance-pinned:
        // build the reference plans on the reference integrator.
        let ref_plans = ref_tfi.prepare_plans(&f, 1).unwrap();
        let exec = PreparedFieldExecutor::new(tfi, &f, 1, 8).unwrap();
        for k in 0..3 {
            let input: Vec<f32> = (0..120).map(|i| ((i + 31 * k) as f32 * 0.05).sin()).collect();
            let got = exec.run_one(&input).unwrap();
            let x = decode(&input, 120).unwrap();
            let want = encode(ref_tfi.integrate_prepared_legacy(&x, &ref_plans).unwrap());
            assert_eq!(got, want, "request {k}: served response must match the legacy path");
        }
    }

    #[test]
    fn malformed_request_maps_to_exec_error_without_killing_workers() {
        let mut rng = Pcg::seed(2);
        let tree = generators::random_tree(24, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let server = InferenceServer::start(
            vec![Box::new(move || {
                let tfi = TreeFieldIntegrator::builder(&tree).build().expect("valid tree");
                Box::new(PreparedFieldExecutor::new(tfi, &f, 1, 4).expect("plannable f"))
                    as Box<dyn BatchExecutor>
            })],
            BatcherConfig { batch_size: 1, batch_timeout: Duration::from_millis(1) },
            64,
        );
        // Wrong-length field: must come back as ServerError::Exec (the
        // FtfiError::ShapeMismatch string), not crash the worker.
        let bad = server.submit_blocking(vec![1.0f32; 7]).unwrap();
        match bad.wait() {
            Err(ServerError::Exec(msg)) => {
                assert!(msg.contains("shape mismatch"), "unexpected message: {msg}")
            }
            other => panic!("expected Exec error, got {other:?}"),
        }
        // The worker survived: a well-formed request still succeeds.
        let good = server.submit_blocking(vec![1.0f32; 24]).unwrap();
        let out = good.wait().expect("worker should still be alive");
        assert_eq!(out.len(), 24);
        server.shutdown();
    }

    #[test]
    fn malformed_request_fails_alone_inside_a_batch() {
        let mut rng = Pcg::seed(4);
        let tree = generators::random_tree(16, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.3, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
        let exec = PreparedFieldExecutor::new(tfi, &f, 1, 4).unwrap();
        let good = vec![1.0f32; 16];
        let bad = vec![1.0f32; 7];
        let results = exec.execute_each(&[good.clone(), bad, good]);
        assert_eq!(results.len(), 3);
        assert!(results[0].is_ok());
        match &results[1] {
            Err(e) => assert!(e.contains("shape mismatch"), "{e}"),
            Ok(_) => panic!("malformed request must fail"),
        }
        assert!(results[2].is_ok(), "batch-mates must not be poisoned");
    }

    #[test]
    fn parallel_execute_each_is_ordered_and_bit_identical_to_serial() {
        let mut rng = Pcg::seed(5);
        let tree = generators::random_tree(700, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
        let serial = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        let par = TreeFieldIntegrator::builder(&tree).threads(4).build().unwrap();
        let exec_s = PreparedFieldExecutor::new(serial, &f, 1, 8).unwrap();
        let exec_p = PreparedFieldExecutor::new(par, &f, 1, 8).unwrap();
        let inputs: Vec<Vec<f32>> = (0..6)
            .map(|k| (0..700).map(|i| ((i + 137 * k) as f32 * 0.01).sin()).collect())
            .collect();
        let a = exec_s.execute_each(&inputs);
        let b = exec_p.execute_each(&inputs);
        assert_eq!(a.len(), b.len());
        for (i, (ra, rb)) in a.iter().zip(&b).enumerate() {
            let (ra, rb) = (ra.as_ref().unwrap(), rb.as_ref().unwrap());
            assert_eq!(ra, rb, "request {i}: parallel response must be bit-identical");
        }
    }

    /// One thread budget end to end: the generic executor must reuse the
    /// integrator's pool rather than stacking a second auto-sized one.
    #[test]
    fn generic_executor_reuses_the_integrator_pool() {
        use crate::ftfi::GraphFieldIntegrator;
        let mut rng = Pcg::seed(6);
        let g = generators::path_plus_random_edges(20, 10, &mut rng);
        let gfi = GraphFieldIntegrator::builder(&g).threads(3).build().unwrap();
        let shared = Arc::clone(gfi.tree_integrator().pool());
        let exec = FieldExecutor::new(gfi, FDist::Identity, 4);
        assert!(Arc::ptr_eq(&exec.pool, &shared), "executor must reuse the integrator's pool");
        assert_eq!(exec.pool.threads(), 3);
    }

    #[test]
    fn generic_executor_works_over_any_backend() {
        use crate::ftfi::GraphFieldIntegrator;
        let mut rng = Pcg::seed(3);
        let g = generators::path_plus_random_edges(30, 15, &mut rng);
        let gfi = GraphFieldIntegrator::try_new(&g).unwrap();
        let exec = FieldExecutor::new(gfi, FDist::Identity, 4);
        let x = vec![1.0f32; 30];
        let out = exec.execute(&[x]).unwrap();
        assert_eq!(out[0].len(), 30);
        // Empty input is a shape error, not a panic.
        assert!(exec.execute(&[vec![]]).is_err());
    }

    fn stream_exec(
        n: usize,
        refresh_every: usize,
        slots: usize,
        seed: u64,
    ) -> StreamingFieldExecutor {
        let mut rng = Pcg::seed(seed);
        let tree = generators::random_tree(n, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        StreamingFieldExecutor::new(tfi, &f, 1, refresh_every, slots, 8).unwrap()
    }

    fn set_req(sid: usize, field: &[f32]) -> Vec<f32> {
        let mut r = vec![STREAM_OP_SET, sid as f32];
        r.extend_from_slice(field);
        r
    }

    fn update_req(sid: usize, rows: &[u32], vals: &[f32]) -> Vec<f32> {
        let mut r = vec![STREAM_OP_UPDATE, sid as f32, rows.len() as f32];
        r.extend(rows.iter().map(|&v| v as f32));
        r.extend_from_slice(vals);
        r
    }

    /// Two sessions with different fields: each session's responses
    /// must track its *own* field, including after interleaved updates
    /// — no cross-contamination through the shared tree / plans.
    #[test]
    fn streaming_sessions_do_not_cross_contaminate() {
        let n = 32;
        let exec = stream_exec(n, 4, 4, 11);
        let fa: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
        let fb: Vec<f32> = (0..n).map(|i| -(i as f32) * 0.2).collect();
        let outs = exec.execute(&[set_req(0, &fa), set_req(1, &fb)]).unwrap();
        assert_ne!(outs[0], outs[1]);
        // Interleave updates; session 1's output must stay what a fresh
        // session with the same field history produces.
        let u0 = exec.run_one(&update_req(0, &[3], &[9.0])).unwrap();
        let u1 = exec.run_one(&update_req(1, &[5], &[-7.0])).unwrap();
        assert_ne!(u0, u1);
        let fresh = stream_exec(n, 4, 4, 11); // same tree seed → same metric
        fresh.run_one(&set_req(0, &fb)).unwrap();
        let want = fresh.run_one(&update_req(0, &[5], &[-7.0])).unwrap();
        assert_eq!(u1, want, "session 1 must behave like an isolated session");
    }

    /// Malformed streaming requests fail alone: the session keeps its
    /// state, batch-mates keep their responses, and the worker (here:
    /// the executor) stays serviceable.
    #[test]
    fn streaming_malformed_update_fails_alone_without_poisoning_the_session() {
        let n = 24;
        let exec = stream_exec(n, 0, 2, 12);
        let field: Vec<f32> = (0..n).map(|i| (i as f32 * 0.3).sin()).collect();
        let base = exec.run_one(&set_req(0, &field)).unwrap();
        let bad_cases: Vec<Vec<f32>> = vec![
            vec![], // no header
            vec![3.0, 0.0, 1.0], // unknown opcode
            vec![STREAM_OP_UPDATE, 9.0, 0.0], // unknown session
            update_req(1, &[], &[]), // session never set
            update_req(0, &[24], &[1.0]), // row out of range
            update_req(0, &[0, 1], &[1.0]), // missing values
            vec![STREAM_OP_UPDATE, 0.0, 2.5, 1.0], // fractional row count
            vec![STREAM_OP_REPLAN, 0.0, 0.0, 1.0], // truncated replan (needs u, v, w)
            vec![STREAM_OP_REPLAN, 0.0, 99.0, 0.0, 1.0], // replan vertex out of range
            vec![STREAM_OP_REPLAN, 0.0, 0.0, 1.0, f32::NAN], // replan weight not finite
            vec![STREAM_OP_REPLAN, 1.0, 0.0, 1.0, 2.0], // replan on a never-set session
        ];
        let good = update_req(0, &[2], &[5.0]);
        let mut batch = bad_cases.clone();
        batch.push(good.clone());
        let results = exec.execute_each(&batch);
        for (i, r) in results[..bad_cases.len()].iter().enumerate() {
            assert!(r.is_err(), "malformed request {i} must fail");
        }
        let ok = results.last().unwrap().as_ref().expect("good batch-mate must succeed");
        // The good update saw the *original* session state: none of the
        // malformed requests may have mutated it.
        let fresh = stream_exec(n, 0, 2, 12);
        let fresh_base = fresh.run_one(&set_req(0, &field)).unwrap();
        assert_eq!(base, fresh_base);
        let want = fresh.run_one(&good).unwrap();
        assert_eq!(*ok, want, "failed requests must not have poisoned the session");
    }

    /// A replan request reweights the shared metric in place; the
    /// response must be **bit-identical** to a fresh executor built
    /// over the already-mutated tree (the in-place re-plan's rebuild
    /// equivalence, end to end through the wire protocol).
    #[test]
    fn streaming_replan_requests_reweight_the_shared_metric() {
        let n = 28;
        let mut rng = Pcg::seed(14);
        let tree = generators::random_tree(n, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        let exec = StreamingFieldExecutor::new(tfi, &f, 1, 0, 2, 8).unwrap();
        let field: Vec<f32> = (0..n).map(|i| (i as f32 * 0.2).cos()).collect();
        let base = exec.run_one(&set_req(0, &field)).unwrap();
        let (eu, ev, ew) = tree.edges()[3];
        let w = (ew * 4.0) as f32;
        let got =
            exec.run_one(&[STREAM_OP_REPLAN, 0.0, eu as f32, ev as f32, w].to_vec()).unwrap();
        assert_ne!(got, base, "reweighting an edge must move the output");
        // Replaying the same weight is a no-op returning the same output.
        let again =
            exec.run_one(&[STREAM_OP_REPLAN, 0.0, eu as f32, ev as f32, w].to_vec()).unwrap();
        assert_eq!(got, again, "same-weight replan must be a no-op");
        // Oracle: a fresh executor over the mutated tree.
        let mut mt = tree.clone();
        assert!(mt.set_edge_weight(eu as usize, ev as usize, w as f64).is_some());
        let tfi2 = TreeFieldIntegrator::builder(&mt).threads(1).build().unwrap();
        let exec2 = StreamingFieldExecutor::new(tfi2, &f, 1, 0, 2, 8).unwrap();
        let want = exec2.run_one(&set_req(0, &field)).unwrap();
        assert_eq!(got, want, "post-replan output must match a rebuilt executor bit-for-bit");
    }

    /// End-to-end through the InferenceServer: streaming workers share
    /// one session table, shutdown drains every in-flight update, and
    /// the update-latency percentiles are populated.
    #[test]
    fn streaming_server_drains_updates_and_reports_update_latency() {
        let n = 16;
        let exec = Arc::new(stream_exec(n, 3, 2, 13));
        let metrics = Arc::clone(exec.metrics_registry());
        let factories: Vec<Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>> = (0..2)
            .map(|_| {
                let exec = Arc::clone(&exec);
                Box::new(move || {
                    Box::new(exec) as Box<dyn BatchExecutor>
                }) as Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>
            })
            .collect();
        let server = InferenceServer::start(
            factories,
            BatcherConfig { batch_size: 4, batch_timeout: Duration::from_millis(1) },
            64,
        );
        let field = vec![1.0f32; n];
        server.submit_blocking(set_req(0, &field)).unwrap().wait().unwrap();
        let handles: Vec<_> = (0..20)
            .map(|i| {
                server
                    .submit_blocking(update_req(0, &[(i % n) as u32], &[i as f32]))
                    .unwrap()
            })
            .collect();
        server.shutdown(); // must drain every in-flight update
        let mut ok = 0;
        for h in handles {
            match h.wait() {
                Ok(out) => {
                    assert_eq!(out.len(), n);
                    ok += 1;
                }
                Err(e) => panic!("update lost during shutdown: {e}"),
            }
        }
        assert_eq!(ok, 20);
        let m = metrics.snapshot();
        assert_eq!(m.updates, 20, "every update must be recorded");
        assert!(m.update_p50 > 0.0 && m.update_p50 <= m.update_p95);
        assert!(m.update_p95 <= m.update_p99);
    }

    /// Ensemble serving path: the generic executor over an
    /// [`EnsembleFieldIntegrator`] shares the ensemble's pool, fans
    /// batches out, and isolates per-request failures.
    #[test]
    fn ensemble_executor_batch_fanout_and_error_isolation() {
        use crate::ftfi::ensemble::EnsembleFieldIntegrator;
        let mut rng = Pcg::seed(21);
        let g = generators::path_plus_random_edges(30, 15, &mut rng);
        let ens = EnsembleFieldIntegrator::builder(&g).trees(3).seed(5).build().unwrap();
        let shared = Arc::clone(ens.pool());
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let exec = FieldExecutor::new(ens, f, 4);
        assert!(
            Arc::ptr_eq(&exec.pool, &shared),
            "executor must reuse the ensemble's pool (one thread budget)"
        );
        let good = vec![1.0f32; 30];
        let bad = vec![1.0f32; 7];
        let results = exec.execute_each(&[good.clone(), bad, good]);
        assert_eq!(results.len(), 3);
        assert!(results[0].is_ok());
        match &results[1] {
            Err(e) => assert!(e.contains("shape mismatch"), "{e}"),
            Ok(_) => panic!("malformed request must fail alone"),
        }
        assert!(results[2].is_ok(), "batch-mates must not be poisoned");
        assert_eq!(results[0].as_ref().unwrap(), results[2].as_ref().unwrap());
    }

    /// Ensemble serving path: fixed `(seed, trees)` responses are
    /// bit-identical across thread counts (the CI thread matrix runs
    /// the whole suite under `FTFI_THREADS ∈ {1, 4}`; the explicit
    /// `.threads(..)` knobs pin both engines regardless).
    #[test]
    fn ensemble_executor_is_seed_deterministic_across_thread_counts() {
        use crate::ftfi::ensemble::EnsembleFieldIntegrator;
        let mut rng = Pcg::seed(22);
        // n ≥ 256 so both the batch fan-out and the tree axis engage.
        let g = generators::path_plus_random_edges(300, 150, &mut rng);
        let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
        let build = |threads: usize| {
            let b = EnsembleFieldIntegrator::builder(&g).trees(3).seed(9).threads(threads);
            b.build().unwrap()
        };
        let exec_s = FieldExecutor::new(build(1), f.clone(), 8);
        let exec_p = FieldExecutor::new(build(4), f, 8);
        let inputs: Vec<Vec<f32>> = (0..4)
            .map(|k| (0..300).map(|i| ((i + 97 * k) as f32 * 0.01).sin()).collect())
            .collect();
        let a = exec_s.execute_each(&inputs);
        let b = exec_p.execute_each(&inputs);
        assert_eq!(a.len(), b.len());
        for (i, (ra, rb)) in a.iter().zip(&b).enumerate() {
            let (ra, rb) = (ra.as_ref().unwrap(), rb.as_ref().unwrap());
            assert_eq!(ra, rb, "request {i}: ensemble response must be bit-identical");
        }
    }
}
