//! Field-integration serving: a [`BatchExecutor`] that answers
//! `Σ_u f(dist(v,u))·x[u]` requests over a fixed metric, plugging the
//! FTFI stack into the coordinator's queue/batcher/worker machinery.
//!
//! Three flavours:
//!
//! - [`FieldExecutor`] runs any [`FieldIntegrator`] backend (tree,
//!   MST-of-graph, brute reference) — one planning pass per request.
//! - [`PreparedFieldExecutor`] owns a [`TreeFieldIntegrator`] plus the
//!   [`PreparedPlans`] for one `f`, so every request reuses the frozen
//!   cross-block plans — the "build once, integrate any number of
//!   fields" serving pattern of §3.1/§3.2.
//! - [`StreamingFieldExecutor`] serves the *online* workload: stateful
//!   per-session [`StreamingIntegrator`]s behind one shared tree / plan
//!   set, answering sparse `apply_update` requests through the delta
//!   fast path (wire protocol below) with per-update latency
//!   percentiles in the [`MetricsRegistry`].
//!
//! Error contract: every [`FtfiError`] (shape mismatches above all) is
//! stringified into a per-request `Err(String)` via
//! [`BatchExecutor::execute_each`], which the batcher delivers as
//! `ServerError::Exec` to that request alone — a malformed request
//! fails its own response without poisoning its batch-mates, and can
//! never panic a worker thread.
//!
//! Both executors fan fused batches out across a [`WorkPool`] — the
//! serving batch axis — so one worker drives all cores of its budget.
//! Responses keep their request order and stay bit-identical to serial
//! execution (the pool's determinism contract). Share one pool across
//! workers (builder `.pool(..)` / [`FieldExecutor::with_pool`]) to bound
//! the process-wide thread count.

use super::batcher::BatchExecutor;
use super::metrics::{MetricsRegistry, MetricsSnapshot};
use super::protocol::{self, RejectReason, StreamRequest, StreamResponse};
use crate::config::CacheConfig;
use crate::ftfi::functions::FDist;
use crate::ftfi::streaming::{SharedPlans, StreamingIntegrator};
use crate::ftfi::{FieldIntegrator, FtfiError, TreeFieldIntegrator};
use crate::linalg::lanes::Precision;
use crate::linalg::matrix::Matrix;
use crate::runtime::pool::{WorkPool, PAR_MAP_MIN_N};
// Session locks come from the crate-wide sync shim so loom can model the
// set-vs-update race; Arc deliberately stays `std` (see `crate::sync`).
use crate::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use crate::sync::Mutex;
use crate::tree::integrator_tree::{PreparedPlans, WorkspaceSizes};
use crate::tree::Tree;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Instant;

/// Decode one flattened request into an `n×d` field (row-major, rows
/// indexed by vertex id). The request length must be a non-zero
/// multiple of `n`.
fn decode(input: &[f32], n: usize) -> Result<Matrix, FtfiError> {
    if input.is_empty() || n == 0 || input.len() % n != 0 {
        return Err(FtfiError::ShapeMismatch { expected: n, got: input.len() });
    }
    let d = input.len() / n;
    Ok(Matrix::from_vec(n, d, input.iter().map(|&v| v as f64).collect()))
}

fn encode(m: Matrix) -> Vec<f32> {
    m.data().iter().map(|&v| v as f32).collect()
}

/// Serve integrations of a fixed `f` through any [`FieldIntegrator`]
/// backend. `I: Sync` because fused batches fan out across the pool's
/// threads (every integrator in this crate is `Sync`).
pub struct FieldExecutor<I: FieldIntegrator + Sync + 'static> {
    integrator: I,
    f: FDist,
    max_batch: usize,
    pool: Arc<WorkPool>,
}

impl<I: FieldIntegrator + Sync + 'static> FieldExecutor<I> {
    /// Build reusing the integrator's own work pool when it has one
    /// (so the batch fan-out and the integrator's internal forks share
    /// one thread budget), else an auto-sized pool (`FTFI_THREADS`,
    /// else all cores).
    pub fn new(integrator: I, f: FDist, max_batch: usize) -> Self {
        let pool = integrator
            .work_pool()
            .cloned()
            .unwrap_or_else(|| Arc::new(WorkPool::with_auto(0)));
        Self::with_pool(integrator, f, max_batch, pool)
    }

    /// Build over a shared work pool (bounds the process-wide thread
    /// budget when several workers serve side by side).
    pub fn with_pool(integrator: I, f: FDist, max_batch: usize, pool: Arc<WorkPool>) -> Self {
        FieldExecutor { integrator, f, max_batch: max_batch.max(1), pool }
    }

    fn run_one(&self, input: &[f32]) -> Result<Vec<f32>, String> {
        let x = decode(input, self.integrator.n()).map_err(|e| e.to_string())?;
        let out = self.integrator.integrate(&self.f, &x).map_err(|e| e.to_string())?;
        Ok(encode(out))
    }
}

impl<I: FieldIntegrator + Sync + 'static> BatchExecutor for FieldExecutor<I> {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        self.execute_each(inputs).into_iter().collect()
    }

    /// Requests fail independently: a malformed request gets its own
    /// `Err` while its batch-mates still succeed. Requests fan out
    /// across the work pool (unless the metric is too small to justify
    /// helper threads); responses keep the request order.
    fn execute_each(&self, inputs: &[Vec<f32>]) -> Vec<Result<Vec<f32>, String>> {
        if self.integrator.n() < PAR_MAP_MIN_N {
            return inputs.iter().map(|input| self.run_one(input)).collect();
        }
        self.pool.map(inputs, |_, input| self.run_one(input))
    }
}

/// Serve integrations of a fixed `f` with prepared plans: the Chebyshev
/// expansions / lattice FFT tables / separable decompositions are built
/// once at construction and reused for every request.
pub struct PreparedFieldExecutor {
    tfi: TreeFieldIntegrator,
    plans: PreparedPlans,
    max_batch: usize,
}

impl PreparedFieldExecutor {
    /// Freeze `f` (with a `channels` width hint for the planner) into a
    /// serving executor. Fails with a typed [`FtfiError`] — e.g. a
    /// forced-but-inapplicable strategy in the integrator's policy —
    /// instead of panicking inside a worker thread later.
    pub fn new(
        tfi: TreeFieldIntegrator,
        f: &FDist,
        channels: usize,
        max_batch: usize,
    ) -> Result<Self, FtfiError> {
        let plans = tfi.prepare_plans(f, channels)?;
        Ok(PreparedFieldExecutor { tfi, plans, max_batch: max_batch.max(1) })
    }

    /// Number of vertices a request row must cover.
    pub fn n(&self) -> usize {
        self.tfi.n()
    }

    fn run_one(&self, input: &[f32]) -> Result<Vec<f32>, String> {
        let x = decode(input, self.tfi.n()).map_err(|e| e.to_string())?;
        let out = self.tfi.integrate_prepared(&x, &self.plans).map_err(|e| e.to_string())?;
        Ok(encode(out))
    }
}

impl BatchExecutor for PreparedFieldExecutor {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        self.execute_each(inputs).into_iter().collect()
    }

    /// Requests fail independently: a malformed request gets its own
    /// `Err` while its batch-mates still succeed. Requests fan out
    /// across the integrator's work pool (set per builder via
    /// `.threads(..)` / `.pool(..)`) unless the metric is too small to
    /// justify helper threads; responses keep the request order.
    fn execute_each(&self, inputs: &[Vec<f32>]) -> Vec<Result<Vec<f32>, String>> {
        if self.tfi.n() < PAR_MAP_MIN_N {
            return inputs.iter().map(|input| self.run_one(input)).collect();
        }
        self.tfi.pool().map(inputs, |_, input| self.run_one(input))
    }
}

/// Opcode of a streaming request (`input[0]`): install/overwrite a
/// session's full field.
pub const STREAM_OP_SET: f32 = 0.0;
/// Opcode of a streaming request (`input[0]`): sparse row update.
pub const STREAM_OP_UPDATE: f32 = 1.0;
/// Opcode of a streaming request (`input[0]`): reweight one tree edge
/// of the shared metric (every session sees the change).
pub const STREAM_OP_REPLAN: f32 = 2.0;

/// Default bound on concurrently in-flight updates per session before
/// admission control answers `Rejected { SessionBusy }`.
pub const DEFAULT_MAX_PENDING: usize = 32;

/// Leaf threshold every cache-built graph is preprocessed with (the
/// builder default). It is part of the canonical graph key, so a future
/// knob cannot silently alias plans built under different thresholds.
const GRAPH_LEAF_THRESHOLD: usize = 32;

/// One cached graph: its shared plan cell plus the LRU/byte-budget
/// bookkeeping.
struct CacheEntry {
    shared: Arc<SharedPlans>,
    /// Estimated resident bytes (prewarmed workspaces + one in-flight).
    bytes: usize,
    last_used: u64,
}

struct CacheState {
    /// Canonical graph key (see `StreamingFieldExecutor::graph_key`) →
    /// entry. A full byte key — not a fixed-width hash — so two distinct
    /// graphs can never collide into a wrong-graph answer.
    map: BTreeMap<Vec<u8>, CacheEntry>,
    /// LRU clock (monotone per cache operation).
    clock: u64,
    /// Sum of entry byte estimates.
    bytes: usize,
    /// Element-wise maxima of the entries' [`WorkspaceSizes`]; every
    /// entry's pools are prewarmed at these, so a session migrating
    /// between cached graphs re-warms zero allocations.
    maxima: Option<WorkspaceSizes>,
}

/// LRU cache of prepared graph entries — the multi-graph serving path.
/// Keyed by the canonical serialized graph (vertex count, sorted
/// `(min, max, weight-bits)` edges, build-option fingerprint), bounded
/// by an entry count and an optional byte budget (`[cache]` config).
/// Eviction only drops the cache's `Arc` — sessions riding the evicted
/// plans keep theirs and stay correct; the entry is rebuilt on the next
/// miss. Lock order: cache state, then (for prewarming) a plan cell's
/// read lock — the cache lock is never taken while a session or plan
/// lock is held.
pub struct PlanCache {
    state: Mutex<CacheState>,
    max_graphs: usize,
    /// `0` = unbounded.
    max_bytes: usize,
    /// Idle workspaces stocked per entry at the cache-wide maxima.
    prewarm: usize,
}

impl PlanCache {
    fn new(max_graphs: usize, max_bytes: usize, prewarm: usize) -> Self {
        PlanCache {
            state: Mutex::new(CacheState {
                map: BTreeMap::new(),
                clock: 0,
                bytes: 0,
                maxima: None,
            }),
            max_graphs: max_graphs.max(1),
            max_bytes,
            prewarm: prewarm.max(1),
        }
    }

    /// The serving hot path (xtask hot-path manifest): resolve a
    /// canonical key to its plan cell and stamp the LRU clock. No
    /// allocation — the key was built by the caller, the hit hands back
    /// an `Arc`.
    fn cache_lookup(&self, key: &[u8]) -> Option<Arc<SharedPlans>> {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.clock += 1;
        let clock = st.clock;
        let entry = st.map.get_mut(key)?;
        entry.last_used = clock;
        Some(Arc::clone(&entry.shared))
    }

    /// Insert a freshly built entry, prewarm it (and, when the
    /// cache-wide maxima grew, top every resident entry up) at the
    /// maxima, then evict LRU-first down to the entry/byte budgets.
    /// Returns `(evicted, graphs, bytes)` for the metrics gauges.
    fn insert(
        &self,
        key: Vec<u8>,
        shared: &Arc<SharedPlans>,
        bytes: usize,
        sizes: WorkspaceSizes,
        d: usize,
    ) -> (u64, u64, u64) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.clock += 1;
        let clock = st.clock;
        let (maxima, grew) = match st.maxima {
            None => (sizes, false),
            Some(m) => {
                let folded = m.max_with(&sizes);
                let grew = folded.slab_rows > m.slab_rows
                    || folded.agg_rows > m.agg_rows
                    || folded.fft_len > m.fft_len
                    || folded.cheb_rank > m.cheb_rank
                    || folded.rat_len > m.rat_len;
                (folded, grew)
            }
        };
        st.maxima = Some(maxima);
        let _ = shared.with(|_, plans| plans.prewarm(self.prewarm, &maxima, d));
        if grew {
            for entry in st.map.values() {
                let _ = entry.shared.with(|_, plans| plans.prewarm(self.prewarm, &maxima, d));
            }
        }
        if let Some(old) = st.map.insert(key, CacheEntry {
            shared: Arc::clone(shared),
            bytes,
            last_used: clock,
        }) {
            st.bytes -= old.bytes;
        }
        st.bytes += bytes;
        let mut evicted = 0u64;
        while st.map.len() > self.max_graphs
            || (self.max_bytes > 0 && st.bytes > self.max_bytes && st.map.len() > 1)
        {
            // LRU victim; the just-inserted entry carries the max clock
            // so it can only be the victim when it is the sole resident
            // (and then the count guard keeps it).
            let victim = st
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    if let Some(e) = st.map.remove(&k) {
                        st.bytes -= e.bytes;
                        evicted += 1;
                    }
                }
                None => break,
            }
        }
        (evicted, st.map.len() as u64, st.bytes as u64)
    }

    /// Resident graph count (tests).
    pub fn graphs(&self) -> usize {
        self.state.lock().unwrap_or_else(|e| e.into_inner()).map.len()
    }
}

/// One leased session: the integrator behind its serialising mutex,
/// plus the admission-control state (in-flight counter, LRU stamp).
struct SessionEntry {
    cell: Mutex<StreamingIntegrator>,
    pending: AtomicUsize,
    last_used: AtomicU64,
}

/// Serve the streaming/online workload: per-session
/// [`StreamingIntegrator`]s sharing one tree, one frozen plan set and
/// one work pool. Requests ride the coordinator's `Vec<f32>` queue in
/// one of two encodings, told apart by the first word:
///
/// - **Typed** ([`protocol`]): a NaN-boxed frame payload carrying a
///   [`StreamRequest`] (`Set`/`Update`/`ReplanEdge`/`Close`/`Lease`);
///   the response is a [`StreamResponse`] frame with the request's id
///   echoed. Decode failures return `Err("protocol: …")`, which the
///   server boundary maps to `ServerError::Protocol` — the frame fails
///   alone.
/// - **Legacy** (`[op, session, …]` f32, the `--wire legacy` shim):
///   parsed into the same typed enum by [`protocol::legacy_to_request`]
///   at this boundary, answered with the bare `n·d` output vector the
///   old wire promised.
///
/// **Admission control**: sessions are *leased* entries in a
/// `max_sessions`-bounded table keyed by client-chosen `u32` ids. A
/// `Set` for a new id evicts the least-recently-used lease when the
/// table is full (the victim's later requests get a typed
/// `Rejected { Evicted }` until it re-`Set`s — the evicted-id ledger
/// holds one entry per distinct evicted id and is cleared by re-`Set`
/// or `Close`). Per-session in-flight updates are bounded by
/// `max_pending`; excess gets `Rejected { SessionBusy }`.
///
/// Updates run the sparse delta fast path with the session's
/// `refresh_every` drift policy; replans reweight one edge of the
/// *shared* metric in place (the O(log n) in-place re-plan, see
/// DESIGN.md "Dynamic graphs & edge re-plans") — the issuing session's
/// output is refreshed eagerly and returned, sibling sessions refresh
/// lazily on their next request. A malformed request (unknown
/// opcode/session, bad row, non-tree edge, bad weight, shape mismatch)
/// fails alone — the session keeps its state, the shared plans stay
/// untouched, and batch-mates keep their responses. Sessions are
/// `Mutex`-guarded, so concurrent batch fan-out over *different*
/// sessions parallelises while same-session updates serialise (arrival
/// order within one fused batch is unspecified — clients that need
/// ordering submit one in-flight update per session). Lock ordering:
/// session table before evicted ledger, session mutex before the shared
/// plan lock (never the reverse), so update/replan/evict interleavings
/// cannot deadlock.
pub struct StreamingFieldExecutor {
    /// The *default* graph's plan cell: what the constructor's
    /// integrator serves, what legacy frames and sessions that never
    /// sent an `OpenGraph` resolve to. Pinned for the executor's
    /// lifetime — it does not count against the cache budgets.
    shared: Arc<SharedPlans>,
    /// Cached from the integrator at construction (the integrator now
    /// lives inside the plan cell; these never change afterwards).
    /// `n` is the *default* graph's vertex count — cached graphs carry
    /// their own, read per session.
    n: usize,
    precision: Precision,
    pool: Arc<WorkPool>,
    /// Frozen per-executor build inputs, reused to prepare every
    /// cache-built graph (so all entries share one `f`/width/tier —
    /// the per-graph degrees of freedom live in the canonical key).
    f: FDist,
    channels: usize,
    refresh_every: usize,
    max_batch: usize,
    capacity: usize,
    max_pending: usize,
    /// Fuse same-session `Update` runs within one batch window into a
    /// single delta pass (`[cache] fuse_updates`, default on).
    fuse: bool,
    cache: PlanCache,
    /// `OpenGraph` bindings awaiting their session's next `Set`
    /// (bounded by `capacity`; an overflowing stash drops an arbitrary
    /// stale binding — its client simply re-opens).
    pending_open: Mutex<BTreeMap<u32, (Arc<SharedPlans>, usize)>>,
    sessions: Mutex<BTreeMap<u32, Arc<SessionEntry>>>,
    evicted: Mutex<BTreeSet<u32>>,
    clock: AtomicU64,
    metrics: Arc<MetricsRegistry>,
}

impl StreamingFieldExecutor {
    /// Freeze `f` (with a `channels` planner hint) and allocate
    /// `max_sessions` empty session slots. `refresh_every` is the drift
    /// policy every session is opened with (`0` = delta-only).
    pub fn new(
        tfi: TreeFieldIntegrator,
        f: &FDist,
        channels: usize,
        refresh_every: usize,
        max_sessions: usize,
        max_batch: usize,
    ) -> Result<Self, FtfiError> {
        let plans = tfi.prepare_plans(f, channels)?;
        let n = tfi.n();
        let precision = plans.precision();
        let pool = Arc::clone(tfi.pool());
        let cache_cfg = CacheConfig::default();
        let prewarm = pool.threads().max(1);
        Ok(StreamingFieldExecutor {
            shared: Arc::new(SharedPlans::new(tfi, plans)),
            n,
            precision,
            pool,
            f: f.clone(),
            channels: channels.max(1),
            refresh_every,
            max_batch: max_batch.max(1),
            capacity: max_sessions.max(1),
            max_pending: DEFAULT_MAX_PENDING,
            fuse: cache_cfg.fuse_updates,
            cache: PlanCache::new(
                cache_cfg.max_graphs,
                cache_cfg.max_bytes_mb.saturating_mul(1024 * 1024),
                prewarm,
            ),
            pending_open: Mutex::new(BTreeMap::new()),
            sessions: Mutex::new(BTreeMap::new()),
            evicted: Mutex::new(BTreeSet::new()),
            clock: AtomicU64::new(0),
            metrics: Arc::new(MetricsRegistry::new()),
        })
    }

    /// Configure the multi-graph plan cache and the fusion switch from
    /// a `[cache]` section ([`CacheConfig`]): entry/byte budgets for
    /// `OpenGraph`-built graphs, and whether same-session update runs
    /// within one batch window fuse into a single delta pass.
    pub fn with_cache(mut self, cfg: CacheConfig) -> Self {
        self.fuse = cfg.fuse_updates;
        self.cache = PlanCache::new(
            cfg.max_graphs,
            cfg.max_bytes_mb.saturating_mul(1024 * 1024),
            self.pool.threads().max(1),
        );
        self
    }

    /// The multi-graph plan cache (tests and gauges).
    pub fn plan_cache(&self) -> &PlanCache {
        &self.cache
    }

    /// Bound the per-session in-flight update count (admission control;
    /// 0 is clamped to 1 — a session that can never accept an update
    /// could never serve).
    pub fn with_max_pending(mut self, max_pending: usize) -> Self {
        self.max_pending = max_pending.max(1);
        self
    }

    /// Record into a caller-provided registry (share it with the
    /// server via `InferenceServer::start_with_metrics`, so evictions
    /// and decode failures land in the snapshot the server reports).
    pub fn with_metrics(mut self, metrics: Arc<MetricsRegistry>) -> Self {
        self.metrics = metrics;
        self
    }

    /// Number of vertices a session field must cover.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Session lease capacity.
    pub fn max_sessions(&self) -> usize {
        self.capacity
    }

    /// The serving tier inherited from the integrator at plan-freeze
    /// time (`TreeFieldIntegratorBuilder::precision`): every session's
    /// full integrations, delta updates and refreshes run this tier.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Update-latency percentiles and counters (the streaming SLO);
    /// share the registry with a dashboard via
    /// [`StreamingFieldExecutor::metrics_registry`].
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// The executor's metrics registry (update-latency histogram).
    pub fn metrics_registry(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    /// Advance the LRU clock and stamp `entry` as just-used.
    fn bump(&self, entry: &SessionEntry) {
        let t = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
        entry.last_used.store(t, Ordering::Relaxed);
    }

    /// Resolve a session id to its leased entry, or the typed response
    /// explaining why it has none (`Rejected { Evicted }` for victims
    /// of LRU pressure, an `Error` for ids never `Set`). Table-lock
    /// poisoning is recovered — the map structure is always valid.
    fn lookup(&self, session: u32) -> Result<Arc<SessionEntry>, StreamResponse> {
        let table = self.sessions.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(entry) = table.get(&session) {
            let entry = Arc::clone(entry);
            drop(table);
            self.bump(&entry);
            return Ok(entry);
        }
        drop(table);
        let evicted = self.evicted.lock().unwrap_or_else(|e| e.into_inner());
        if evicted.contains(&session) {
            Err(StreamResponse::Rejected {
                reason: RejectReason::Evicted,
                retry_after_hint_ms: 1,
            })
        } else {
            Err(StreamResponse::Error {
                message: format!("session {session} not initialised (send a set request first)"),
            })
        }
    }

    /// Execute one typed request against the session table. Every
    /// outcome is a typed response — this method never panics and never
    /// poisons a session on a failed request.
    pub fn execute_request(&self, req: &StreamRequest) -> StreamResponse {
        match req {
            StreamRequest::Set { session, rows, channels, values } => {
                self.exec_set(*session, *rows, *channels, values)
            }
            StreamRequest::Update { session, rows, channels, values } => {
                let t0 = Instant::now();
                let resp = self.exec_update(*session, rows, *channels, values);
                if matches!(resp, StreamResponse::Output { .. }) {
                    self.metrics.record_update_latency(t0.elapsed().as_secs_f64());
                }
                resp
            }
            StreamRequest::ReplanEdge { session, u, v, w } => {
                self.exec_replan(*session, *u, *v, *w)
            }
            StreamRequest::Close { session } => self.exec_close(*session),
            StreamRequest::Lease { session } => self.exec_lease(*session),
            StreamRequest::OpenGraph { session, n, edges } => {
                self.exec_open(*session, *n, edges)
            }
        }
    }

    /// Canonicalize an `OpenGraph` edge list into the cache key:
    /// `n`, the build-option fingerprint (leaf threshold + serving
    /// tier), then the edges sorted as `(min, max, weight-bits)`. The
    /// full validation a later `Tree::from_edges` would assert runs
    /// here fallibly — count, vertex range, positive finite weights,
    /// spanning connectivity — so a malformed graph fails its frame
    /// typed instead of panicking a worker.
    fn graph_key(&self, n: usize, edges: &[(u32, u32, f64)]) -> Result<Vec<u8>, String> {
        if n == 0 || edges.len() != n - 1 {
            return Err(format!(
                "open-graph: a tree on {n} vertices needs {} edges, got {}",
                n.saturating_sub(1),
                edges.len()
            ));
        }
        let mut es: Vec<(u32, u32, u64)> = Vec::with_capacity(edges.len());
        for &(u, v, w) in edges {
            if u as usize >= n || v as usize >= n || u == v {
                return Err(format!(
                    "open-graph: edge ({u},{v}) invalid (vertices must be distinct and < {n})"
                ));
            }
            if !w.is_finite() || w <= 0.0 {
                return Err(format!(
                    "open-graph: edge ({u},{v}) has non-positive or non-finite weight {w}"
                ));
            }
            es.push((u.min(v), u.max(v), w.to_bits()));
        }
        es.sort_unstable();
        // Union-find connectivity: n-1 cycle-free edges on n vertices
        // form a spanning tree.
        let mut parent: Vec<u32> = (0..n as u32).collect();
        fn find(parent: &mut [u32], mut x: u32) -> u32 {
            while parent[x as usize] != x {
                parent[x as usize] = parent[parent[x as usize] as usize];
                x = parent[x as usize];
            }
            x
        }
        for &(u, v, _) in &es {
            let (ru, rv) = (find(&mut parent, u), find(&mut parent, v));
            if ru == rv {
                return Err(format!(
                    "open-graph: edge ({u},{v}) closes a cycle — the edge list is not a tree"
                ));
            }
            parent[ru as usize] = rv;
        }
        let mut key = Vec::with_capacity(17 + 16 * es.len());
        key.extend_from_slice(&(n as u64).to_le_bytes());
        key.extend_from_slice(&(GRAPH_LEAF_THRESHOLD as u64).to_le_bytes());
        key.push(match self.precision {
            Precision::F64 => 0,
            Precision::F32 => 1,
        });
        for (u, v, wb) in es {
            key.extend_from_slice(&u.to_le_bytes());
            key.extend_from_slice(&v.to_le_bytes());
            key.extend_from_slice(&wb.to_le_bytes());
        }
        Ok(key)
    }

    /// Build a cache entry for an already-validated edge list: tree →
    /// integrator (sharing the executor's pool and tier) → prepared
    /// plans, all under the executor's frozen `f`/width.
    fn open_graph_build(
        &self,
        n: usize,
        edges: &[(u32, u32, f64)],
    ) -> Result<(Arc<SharedPlans>, WorkspaceSizes, usize), String> {
        let tree = Tree::from_edges(n, edges);
        let tfi = TreeFieldIntegrator::builder(&tree)
            .leaf_threshold(GRAPH_LEAF_THRESHOLD)
            .pool(Arc::clone(&self.pool))
            .precision(self.precision)
            .build()
            .map_err(|e| e.to_string())?;
        let plans = tfi.prepare_plans(&self.f, self.channels).map_err(|e| e.to_string())?;
        let sizes = plans.sizes();
        let bytes = plans
            .workspace_bytes(self.channels)
            .saturating_mul(self.cache.prewarm + 1);
        Ok((Arc::new(SharedPlans::new(tfi, plans)), sizes, bytes))
    }

    /// Bind `session` to the graph given by its edge list. The graph is
    /// resolved through the plan cache (hit: an LRU stamp; miss: build +
    /// prepare + prewarm + LRU eviction down to the budgets). A live
    /// same-size session migrates in place — its field carries over and
    /// the refreshed output is returned. A live different-size session
    /// cannot carry its field: its lease is dropped and the binding is
    /// stashed (like a new session's) for the client's next `Set`, which
    /// is acknowledged with an empty `Output { channels: 0 }`.
    fn exec_open(&self, session: u32, n: u32, edges: &[(u32, u32, f64)]) -> StreamResponse {
        let nv = n as usize;
        let key = match self.graph_key(nv, edges) {
            Ok(k) => k,
            Err(message) => return StreamResponse::Error { message },
        };
        let resolved = match self.cache.cache_lookup(&key) {
            Some(s) => {
                self.metrics.record_cache_hit();
                s
            }
            None => {
                self.metrics.record_cache_miss();
                let (s, sizes, bytes) = match self.open_graph_build(nv, edges) {
                    Ok(t) => t,
                    Err(message) => return StreamResponse::Error { message },
                };
                let (evicted, graphs, bytes_now) =
                    self.cache.insert(key, &s, bytes, sizes, self.channels);
                if evicted > 0 {
                    self.metrics.record_cache_evictions(evicted);
                }
                self.metrics.set_cache_usage(graphs, bytes_now);
                s
            }
        };
        let live = {
            let table = self.sessions.lock().unwrap_or_else(|e| e.into_inner());
            table.get(&session).map(Arc::clone)
        };
        if let Some(entry) = live {
            let mut cell = match entry.cell.lock() {
                Ok(c) => c,
                Err(_) => {
                    return StreamResponse::Error {
                        message: format!("session {session} poisoned by an earlier panic"),
                    }
                }
            };
            if cell.n() == nv {
                self.bump(&entry);
                if let Err(e) = cell.migrate(resolved).map(|_| ()) {
                    return StreamResponse::Error { message: e.to_string() };
                }
                return StreamResponse::Output {
                    session,
                    rows: n,
                    channels: cell.channels() as u32,
                    values: cell.output().data().iter().map(|&v| v as f32).collect(),
                };
            }
            drop(cell);
            self.sessions.lock().unwrap_or_else(|e| e.into_inner()).remove(&session);
        }
        let mut pend = self.pending_open.lock().unwrap_or_else(|e| e.into_inner());
        if pend.len() >= self.capacity && !pend.contains_key(&session) {
            if let Some(&stale) = pend.keys().next() {
                pend.remove(&stale);
            }
        }
        pend.insert(session, (resolved, nv));
        StreamResponse::Output { session, rows: n, channels: 0, values: Vec::new() }
    }

    fn exec_set(&self, session: u32, rows: u32, channels: u32, values: &[f32]) -> StreamResponse {
        // Resolve the session's graph binding: a pending `OpenGraph`
        // wins, else a live lease keeps its current graph, else the
        // default graph — the pre-cache behavior.
        let pending =
            self.pending_open.lock().unwrap_or_else(|e| e.into_inner()).remove(&session);
        let from_pending = pending.is_some();
        let (shared, n) = match pending {
            Some(b) => b,
            None => {
                let live = {
                    let table = self.sessions.lock().unwrap_or_else(|e| e.into_inner());
                    table.get(&session).map(Arc::clone)
                };
                match live {
                    Some(entry) => match entry.cell.lock() {
                        Ok(c) => (Arc::clone(c.shared()), c.n()),
                        Err(_) => {
                            return StreamResponse::Error {
                                message: format!(
                                    "session {session} poisoned by an earlier panic"
                                ),
                            }
                        }
                    },
                    None => (Arc::clone(&self.shared), self.n),
                }
            }
        };
        // A failed Set must not consume the binding the client opened:
        // restore it so the retry lands on the intended graph.
        let restore = |shared: Arc<SharedPlans>, n: usize| {
            if from_pending {
                self.pending_open
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .insert(session, (shared, n));
            }
        };
        if rows as usize != n || channels == 0 {
            restore(shared, n);
            return StreamResponse::Error {
                message: FtfiError::ShapeMismatch { expected: n, got: values.len() }.to_string(),
            };
        }
        let d = channels as usize;
        let field = Matrix::from_vec(n, d, values.iter().map(|&v| v as f64).collect());
        let integ =
            match StreamingIntegrator::new(Arc::clone(&shared), field, self.refresh_every) {
                Ok(s) => s,
                Err(e) => {
                    restore(shared, n);
                    return StreamResponse::Error { message: e.to_string() };
                }
            };
        let out: Vec<f32> = integ.output().data().iter().map(|&v| v as f32).collect();
        let mut table = self.sessions.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(entry) = table.get(&session) {
            // Re-`Set` of a live lease: swap the integrator in place so
            // concurrent same-session requests stay serialised.
            let entry = Arc::clone(entry);
            drop(table);
            match entry.cell.lock() {
                Ok(mut cell) => *cell = integ,
                Err(_) => {
                    return StreamResponse::Error {
                        message: format!("session {session} poisoned by an earlier panic"),
                    }
                }
            }
            self.bump(&entry);
        } else {
            if table.len() >= self.capacity {
                // LRU eviction: the victim's id moves to the evicted
                // ledger so its later requests get a typed rejection.
                let victim = table
                    .iter()
                    .min_by_key(|(_, e)| e.last_used.load(Ordering::Relaxed))
                    .map(|(&id, _)| id);
                if let Some(victim) = victim {
                    table.remove(&victim);
                    self.evicted.lock().unwrap_or_else(|e| e.into_inner()).insert(victim);
                    self.metrics.record_eviction();
                }
            }
            let entry = Arc::new(SessionEntry {
                cell: Mutex::new(integ),
                pending: AtomicUsize::new(0),
                last_used: AtomicU64::new(0),
            });
            self.bump(&entry);
            table.insert(session, entry);
            drop(table);
            // A re-`Set` re-admits a previously evicted id.
            self.evicted.lock().unwrap_or_else(|e| e.into_inner()).remove(&session);
        }
        StreamResponse::Output { session, rows, channels, values: out }
    }

    fn exec_update(
        &self,
        session: u32,
        rows: &[u32],
        channels: u32,
        values: &[f32],
    ) -> StreamResponse {
        let entry = match self.lookup(session) {
            Ok(e) => e,
            Err(resp) => return resp,
        };
        // Bounded per-session in-flight updates: the counter spans the
        // cell-lock wait, so a flooded session sheds instead of growing
        // an unbounded convoy on its mutex.
        if entry.pending.fetch_add(1, Ordering::Relaxed) >= self.max_pending {
            entry.pending.fetch_sub(1, Ordering::Relaxed);
            return StreamResponse::Rejected {
                reason: RejectReason::SessionBusy,
                retry_after_hint_ms: 2,
            };
        }
        let resp = self.exec_update_locked(&entry, session, rows, channels, values);
        entry.pending.fetch_sub(1, Ordering::Relaxed);
        resp
    }

    fn exec_update_locked(
        &self,
        entry: &SessionEntry,
        session: u32,
        rows: &[u32],
        channels: u32,
        values: &[f32],
    ) -> StreamResponse {
        let mut cell = match entry.cell.lock() {
            Ok(c) => c,
            Err(_) => {
                return StreamResponse::Error {
                    message: format!("session {session} poisoned by an earlier panic"),
                }
            }
        };
        // Validate against the *session's* graph (multi-graph sessions
        // carry their own vertex count, not the default graph's).
        let n = cell.n();
        for &r in rows {
            if r as usize >= n {
                return StreamResponse::Error {
                    message: format!("row {r} invalid (expected an integer in 0..{n})"),
                };
            }
        }
        let d = cell.channels();
        // channels = 0 is the legacy shim's "infer from the session";
        // a typed non-zero width must match the lease it addresses.
        if channels != 0 && channels as usize != d {
            return StreamResponse::Error {
                message: format!("update width {channels} does not match the session's {d}"),
            };
        }
        let k = rows.len();
        if values.len() != k * d {
            return StreamResponse::Error {
                message: FtfiError::ShapeMismatch { expected: k * d, got: values.len() }
                    .to_string(),
            };
        }
        let vm = Matrix::from_vec(k, d, values.iter().map(|&v| v as f64).collect());
        match cell.apply_update(rows, &vm) {
            Ok(out) => StreamResponse::Output {
                session,
                rows: n as u32,
                channels: d as u32,
                values: out.data().iter().map(|&v| v as f32).collect(),
            },
            Err(e) => StreamResponse::Error { message: e.to_string() },
        }
    }

    /// Reweight the tree edge `{u, v}` to `w`. The session mutex is
    /// taken *before* the shared plan lock (the crate-wide lock order);
    /// validation failures surface as this request's typed error with
    /// the plans and every session untouched.
    fn exec_replan(&self, session: u32, u: u32, v: u32, w: f64) -> StreamResponse {
        let entry = match self.lookup(session) {
            Ok(e) => e,
            Err(resp) => return resp,
        };
        let mut cell = match entry.cell.lock() {
            Ok(c) => c,
            Err(_) => {
                return StreamResponse::Error {
                    message: format!("session {session} poisoned by an earlier panic"),
                }
            }
        };
        let n = cell.n();
        if u as usize >= n || v as usize >= n {
            return StreamResponse::Error {
                message: format!("vertex invalid (expected an integer in 0..{n})"),
            };
        }
        if let Err(e) = cell.update_edge(u as usize, v as usize, w) {
            return StreamResponse::Error { message: e.to_string() };
        }
        StreamResponse::Output {
            session,
            rows: n as u32,
            channels: cell.channels() as u32,
            values: cell.output().data().iter().map(|&v| v as f32).collect(),
        }
    }

    /// Release a lease. Idempotent: closing an unknown or already
    /// evicted id still acknowledges with `Closed`.
    fn exec_close(&self, session: u32) -> StreamResponse {
        self.sessions.lock().unwrap_or_else(|e| e.into_inner()).remove(&session);
        self.evicted.lock().unwrap_or_else(|e| e.into_inner()).remove(&session);
        self.pending_open.lock().unwrap_or_else(|e| e.into_inner()).remove(&session);
        StreamResponse::Closed { session }
    }

    /// Touch a lease and return its current (possibly lazily-stale)
    /// output.
    fn exec_lease(&self, session: u32) -> StreamResponse {
        let entry = match self.lookup(session) {
            Ok(e) => e,
            Err(resp) => return resp,
        };
        let cell = match entry.cell.lock() {
            Ok(c) => c,
            Err(_) => {
                return StreamResponse::Error {
                    message: format!("session {session} poisoned by an earlier panic"),
                }
            }
        };
        StreamResponse::Output {
            session,
            rows: cell.n() as u32,
            channels: cell.channels() as u32,
            values: cell.output().data().iter().map(|&v| v as f32).collect(),
        }
    }

    /// Execute a whole batch window of `Update`s for one session as a
    /// single fused delta pass ([`StreamingIntegrator::apply_updates_fused`]).
    /// Members keep their FIFO order and full per-member semantics — a
    /// malformed member fails alone, refresh cadences fire per logical
    /// update — and every successful member is answered with the
    /// post-window output (the wire already declares within-batch
    /// ordering unspecified, so intermediate snapshots were never
    /// observable contract). The fused group holds ONE admission slot
    /// (it occupies the session mutex once), mirroring the batcher's
    /// group-at-once shed accounting.
    fn exec_update_group(
        &self,
        session: u32,
        members: &[(&[u32], u32, &[f32])],
    ) -> Vec<StreamResponse> {
        let t0 = Instant::now();
        let entry = match self.lookup(session) {
            Ok(e) => e,
            Err(resp) => return members.iter().map(|_| resp.clone()).collect(),
        };
        if entry.pending.fetch_add(1, Ordering::Relaxed) >= self.max_pending {
            entry.pending.fetch_sub(1, Ordering::Relaxed);
            return members
                .iter()
                .map(|_| StreamResponse::Rejected {
                    reason: RejectReason::SessionBusy,
                    retry_after_hint_ms: 2,
                })
                .collect();
        }
        let out = self.exec_update_group_locked(&entry, session, members, t0);
        entry.pending.fetch_sub(1, Ordering::Relaxed);
        out
    }

    fn exec_update_group_locked(
        &self,
        entry: &SessionEntry,
        session: u32,
        members: &[(&[u32], u32, &[f32])],
        t0: Instant,
    ) -> Vec<StreamResponse> {
        let mut cell = match entry.cell.lock() {
            Ok(c) => c,
            Err(_) => {
                let message = format!("session {session} poisoned by an earlier panic");
                return members
                    .iter()
                    .map(|_| StreamResponse::Error { message: message.clone() })
                    .collect();
            }
        };
        let n = cell.n();
        let d = cell.channels();
        // Executor-level validation per member (row range, width, value
        // count) — failures stage nothing and fail alone, exactly as a
        // one-by-one `exec_update` would answer them.
        let mut staged: Vec<Result<(&[u32], Matrix), String>> = Vec::with_capacity(members.len());
        for &(rows, channels, values) in members {
            if let Some(&r) = rows.iter().find(|&&r| r as usize >= n) {
                staged.push(Err(format!("row {r} invalid (expected an integer in 0..{n})")));
                continue;
            }
            if channels != 0 && channels as usize != d {
                staged.push(Err(format!(
                    "update width {channels} does not match the session's {d}"
                )));
                continue;
            }
            let k = rows.len();
            if values.len() != k * d {
                staged.push(Err(FtfiError::ShapeMismatch { expected: k * d, got: values.len() }
                    .to_string()));
                continue;
            }
            let vm = Matrix::from_vec(k, d, values.iter().map(|&v| v as f64).collect());
            staged.push(Ok((rows, vm)));
        }
        let fusable: Vec<(&[u32], &Matrix)> = staged
            .iter()
            .filter_map(|m| m.as_ref().ok().map(|(rows, vm)| (*rows, vm)))
            .collect();
        let (verdicts, stats) = cell.apply_updates_fused(&fusable);
        self.metrics.record_fusion(stats.fused as u64, stats.rows_saved as u64);
        let out_values: Vec<f32> = cell.output().data().iter().map(|&v| v as f32).collect();
        drop(cell);
        let latency = t0.elapsed().as_secs_f64();
        let mut verdicts = verdicts.into_iter();
        staged
            .into_iter()
            .map(|m| match m {
                Err(message) => StreamResponse::Error { message },
                Ok(_) => match verdicts.next() {
                    Some(Ok(())) => {
                        self.metrics.record_update_latency(latency);
                        StreamResponse::Output {
                            session,
                            rows: n as u32,
                            channels: d as u32,
                            values: out_values.clone(),
                        }
                    }
                    Some(Err(e)) => StreamResponse::Error { message: e.to_string() },
                    None => StreamResponse::Error {
                        message: "fused window dropped a member".to_string(),
                    },
                },
            })
            .collect()
    }

    /// One queue request, either encoding. Typed frames answer with
    /// typed response frames (decode failures become `protocol:`-tagged
    /// errors); legacy frames answer with the bare output vector the
    /// old wire promised.
    fn run_one(&self, input: &[f32]) -> Result<Vec<f32>, String> {
        if protocol::is_typed_words(input) {
            let (req_id, req) = protocol::words_to_payload(input)
                .and_then(|payload| protocol::decode_request(&payload))
                .map_err(|e| {
                    self.metrics.record_protocol_error();
                    format!("{}{e}", protocol::ERR_PROTOCOL_PREFIX)
                })?;
            let resp = self.execute_request(&req);
            Ok(protocol::payload_to_words(&protocol::encode_response(&resp, req_id)))
        } else {
            let req = protocol::legacy_to_request(input, self.n)?;
            match self.execute_request(&req) {
                StreamResponse::Output { values, .. } => Ok(values),
                StreamResponse::Closed { .. } => Ok(Vec::new()),
                StreamResponse::Rejected { reason, .. } => Err(format!("rejected: {reason:?}")),
                StreamResponse::Error { message } => Err(message),
            }
        }
    }
}

/// One batch-window frame after the single decode pass of
/// `execute_each`: which wire it arrived on (typed frames answer with
/// response frames even on failure; legacy frames answer bare), or the
/// decode failure that already answers it.
enum Decoded {
    Typed { req_id: u64, req: StreamRequest },
    Legacy { req: StreamRequest },
    Fail(String),
}

impl Decoded {
    fn request(&self) -> Option<&StreamRequest> {
        match self {
            Decoded::Typed { req, .. } | Decoded::Legacy { req } => Some(req),
            Decoded::Fail(_) => None,
        }
    }

    fn is_update(&self) -> bool {
        matches!(self.request(), Some(StreamRequest::Update { .. }))
    }

    /// Encode a typed response back onto the frame's wire.
    fn finish(&self, resp: StreamResponse) -> Result<Vec<f32>, String> {
        match self {
            Decoded::Typed { req_id, .. } => {
                Ok(protocol::payload_to_words(&protocol::encode_response(&resp, *req_id)))
            }
            Decoded::Legacy { .. } => match resp {
                StreamResponse::Output { values, .. } => Ok(values),
                StreamResponse::Closed { .. } => Ok(Vec::new()),
                StreamResponse::Rejected { reason, .. } => Err(format!("rejected: {reason:?}")),
                StreamResponse::Error { message } => Err(message),
            },
            Decoded::Fail(e) => Err(e.clone()),
        }
    }
}

impl StreamingFieldExecutor {
    fn decode_one(&self, input: &[f32]) -> Decoded {
        if protocol::is_typed_words(input) {
            match protocol::words_to_payload(input)
                .and_then(|payload| protocol::decode_request(&payload))
            {
                Ok((req_id, req)) => Decoded::Typed { req_id, req },
                Err(e) => {
                    self.metrics.record_protocol_error();
                    Decoded::Fail(format!("{}{e}", protocol::ERR_PROTOCOL_PREFIX))
                }
            }
        } else {
            match protocol::legacy_to_request(input, self.n) {
                Ok(req) => Decoded::Legacy { req },
                Err(e) => Decoded::Fail(e),
            }
        }
    }

    /// Run one session's FIFO chain of batch-window frames. Maximal
    /// runs of `Update`s (uninterrupted, for this session, by any other
    /// request kind) fuse into one delta pass when fusion is on; every
    /// other request executes one-by-one in chain order.
    fn run_chain(
        &self,
        chain: &[usize],
        decoded: &[Decoded],
    ) -> Vec<(usize, Result<Vec<f32>, String>)> {
        let mut out = Vec::with_capacity(chain.len());
        let mut i = 0;
        while i < chain.len() {
            if self.fuse && decoded[chain[i]].is_update() {
                let mut j = i + 1;
                while j < chain.len() && decoded[chain[j]].is_update() {
                    j += 1;
                }
                if j - i > 1 {
                    let idxs = &chain[i..j];
                    let mut session = 0u32;
                    let members: Vec<(&[u32], u32, &[f32])> = idxs
                        .iter()
                        .filter_map(|&k| match decoded[k].request() {
                            Some(StreamRequest::Update { session: s, rows, channels, values }) => {
                                session = *s;
                                Some((rows.as_slice(), *channels, values.as_slice()))
                            }
                            _ => None,
                        })
                        .collect();
                    let resps = self.exec_update_group(session, &members);
                    for (&k, resp) in idxs.iter().zip(resps) {
                        out.push((k, decoded[k].finish(resp)));
                    }
                    i = j;
                    continue;
                }
            }
            let idx = chain[i];
            if let Some(req) = decoded[idx].request() {
                let resp = self.execute_request(req);
                out.push((idx, decoded[idx].finish(resp)));
            }
            i += 1;
        }
        out
    }
}

impl BatchExecutor for StreamingFieldExecutor {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        self.execute_each(inputs).into_iter().collect()
    }

    /// Requests fail independently. Frames are decoded once, partitioned
    /// into per-session FIFO chains, and the chains fan out across the
    /// integrator's pool — same-session requests now execute in arrival
    /// order (previously "unspecified within a batch"), while distinct
    /// sessions proceed in parallel. Within a chain, runs of `Update`s
    /// fuse into a single delta pass (see `exec_update_group`) unless
    /// fusion is configured off.
    fn execute_each(&self, inputs: &[Vec<f32>]) -> Vec<Result<Vec<f32>, String>> {
        let decoded: Vec<Decoded> = inputs.iter().map(|input| self.decode_one(input)).collect();
        let mut chain_of: BTreeMap<u32, usize> = BTreeMap::new();
        let mut chains: Vec<Vec<usize>> = Vec::new();
        let mut results: Vec<Option<Result<Vec<f32>, String>>> =
            inputs.iter().map(|_| None).collect();
        for (i, d) in decoded.iter().enumerate() {
            match d {
                Decoded::Fail(e) => results[i] = Some(Err(e.clone())),
                Decoded::Typed { req, .. } | Decoded::Legacy { req } => {
                    let sid = req.session();
                    let c = *chain_of.entry(sid).or_insert_with(|| {
                        chains.push(Vec::new());
                        chains.len() - 1
                    });
                    chains[c].push(i);
                }
            }
        }
        let runs: Vec<Vec<(usize, Result<Vec<f32>, String>)>> =
            if self.n < PAR_MAP_MIN_N || chains.len() < 2 {
                chains.iter().map(|c| self.run_chain(c, &decoded)).collect()
            } else {
                self.pool.map(&chains, |_, c| self.run_chain(c, &decoded))
            };
        for run in runs {
            for (i, r) in run {
                results[i] = Some(r);
            }
        }
        results
            .into_iter()
            .map(|r| r.unwrap_or_else(|| Err("request dropped by the batch window".to_string())))
            .collect()
    }

    /// Shed-accounting fusion key: typed or legacy `Update` frames for
    /// one session share a key, so the batcher sheds a fused group as a
    /// unit (only when *every* member aged) and counts it once. Other
    /// kinds — and any frame when fusion is off — shed per-request.
    fn fuse_key(&self, input: &[f32]) -> Option<u64> {
        if !self.fuse {
            return None;
        }
        let req = if protocol::is_typed_words(input) {
            protocol::words_to_payload(input)
                .and_then(|payload| protocol::decode_request(&payload))
                .ok()?
                .1
        } else {
            protocol::legacy_to_request(input, self.n).ok()?
        };
        match req {
            StreamRequest::Update { session, .. } => Some(u64::from(session)),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, InferenceServer, ServerError};
    use crate::ftfi::brute::btfi;
    use crate::graph::generators;
    use crate::ml::rng::Pcg;
    use std::time::Duration;

    #[test]
    fn prepared_executor_serves_correct_integrals() {
        let mut rng = Pcg::seed(1);
        let tree = generators::random_tree(40, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
        let exec = PreparedFieldExecutor::new(tfi, &f, 1, 8).unwrap();
        let x: Vec<f32> = (0..40).map(|i| (i as f32 * 0.1).sin()).collect();
        let out = exec.execute(&[x.clone()]).unwrap();
        let xm = Matrix::from_vec(40, 1, x.iter().map(|&v| v as f64).collect());
        let want = btfi(&tree, &f, &xm);
        for (got, w) in out[0].iter().zip(want.data()) {
            assert!((*got as f64 - w).abs() < 1e-4 * (1.0 + w.abs()), "{got} vs {w}");
        }
    }

    /// The executor's request loop runs on the workspace hot path
    /// (`integrate_prepared`): responses must stay bit-identical to the
    /// legacy per-node-allocation reference, and repeated requests must
    /// reuse the plan's workspaces without leaking state across them.
    #[test]
    fn prepared_executor_serves_the_workspace_hot_path() {
        let mut rng = Pcg::seed(7);
        let tree = generators::random_tree(120, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        let ref_tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        // Same tree → same IT shape, but plans are instance-pinned:
        // build the reference plans on the reference integrator.
        let ref_plans = ref_tfi.prepare_plans(&f, 1).unwrap();
        let exec = PreparedFieldExecutor::new(tfi, &f, 1, 8).unwrap();
        for k in 0..3 {
            let input: Vec<f32> = (0..120).map(|i| ((i + 31 * k) as f32 * 0.05).sin()).collect();
            let got = exec.run_one(&input).unwrap();
            let x = decode(&input, 120).unwrap();
            let want = encode(ref_tfi.integrate_prepared_legacy(&x, &ref_plans).unwrap());
            assert_eq!(got, want, "request {k}: served response must match the legacy path");
        }
    }

    #[test]
    fn malformed_request_maps_to_exec_error_without_killing_workers() {
        let mut rng = Pcg::seed(2);
        let tree = generators::random_tree(24, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let server = InferenceServer::start(
            vec![Box::new(move || {
                let tfi = TreeFieldIntegrator::builder(&tree).build().expect("valid tree");
                Box::new(PreparedFieldExecutor::new(tfi, &f, 1, 4).expect("plannable f"))
                    as Box<dyn BatchExecutor>
            })],
            BatcherConfig {
                batch_size: 1,
                batch_timeout: Duration::from_millis(1),
                shed_after: None,
            },
            64,
        );
        // Wrong-length field: must come back as ServerError::Exec (the
        // FtfiError::ShapeMismatch string), not crash the worker.
        let bad = server.submit_blocking(vec![1.0f32; 7]).unwrap();
        match bad.wait() {
            Err(ServerError::Exec(msg)) => {
                assert!(msg.contains("shape mismatch"), "unexpected message: {msg}")
            }
            other => panic!("expected Exec error, got {other:?}"),
        }
        // The worker survived: a well-formed request still succeeds.
        let good = server.submit_blocking(vec![1.0f32; 24]).unwrap();
        let out = good.wait().expect("worker should still be alive");
        assert_eq!(out.len(), 24);
        server.shutdown();
    }

    #[test]
    fn malformed_request_fails_alone_inside_a_batch() {
        let mut rng = Pcg::seed(4);
        let tree = generators::random_tree(16, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.3, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
        let exec = PreparedFieldExecutor::new(tfi, &f, 1, 4).unwrap();
        let good = vec![1.0f32; 16];
        let bad = vec![1.0f32; 7];
        let results = exec.execute_each(&[good.clone(), bad, good]);
        assert_eq!(results.len(), 3);
        assert!(results[0].is_ok());
        match &results[1] {
            Err(e) => assert!(e.contains("shape mismatch"), "{e}"),
            Ok(_) => panic!("malformed request must fail"),
        }
        assert!(results[2].is_ok(), "batch-mates must not be poisoned");
    }

    #[test]
    fn parallel_execute_each_is_ordered_and_bit_identical_to_serial() {
        let mut rng = Pcg::seed(5);
        let tree = generators::random_tree(700, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
        let serial = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        let par = TreeFieldIntegrator::builder(&tree).threads(4).build().unwrap();
        let exec_s = PreparedFieldExecutor::new(serial, &f, 1, 8).unwrap();
        let exec_p = PreparedFieldExecutor::new(par, &f, 1, 8).unwrap();
        let inputs: Vec<Vec<f32>> = (0..6)
            .map(|k| (0..700).map(|i| ((i + 137 * k) as f32 * 0.01).sin()).collect())
            .collect();
        let a = exec_s.execute_each(&inputs);
        let b = exec_p.execute_each(&inputs);
        assert_eq!(a.len(), b.len());
        for (i, (ra, rb)) in a.iter().zip(&b).enumerate() {
            let (ra, rb) = (ra.as_ref().unwrap(), rb.as_ref().unwrap());
            assert_eq!(ra, rb, "request {i}: parallel response must be bit-identical");
        }
    }

    /// One thread budget end to end: the generic executor must reuse the
    /// integrator's pool rather than stacking a second auto-sized one.
    #[test]
    fn generic_executor_reuses_the_integrator_pool() {
        use crate::ftfi::GraphFieldIntegrator;
        let mut rng = Pcg::seed(6);
        let g = generators::path_plus_random_edges(20, 10, &mut rng);
        let gfi = GraphFieldIntegrator::builder(&g).threads(3).build().unwrap();
        let shared = Arc::clone(gfi.tree_integrator().pool());
        let exec = FieldExecutor::new(gfi, FDist::Identity, 4);
        assert!(Arc::ptr_eq(&exec.pool, &shared), "executor must reuse the integrator's pool");
        assert_eq!(exec.pool.threads(), 3);
    }

    #[test]
    fn generic_executor_works_over_any_backend() {
        use crate::ftfi::GraphFieldIntegrator;
        let mut rng = Pcg::seed(3);
        let g = generators::path_plus_random_edges(30, 15, &mut rng);
        let gfi = GraphFieldIntegrator::try_new(&g).unwrap();
        let exec = FieldExecutor::new(gfi, FDist::Identity, 4);
        let x = vec![1.0f32; 30];
        let out = exec.execute(&[x]).unwrap();
        assert_eq!(out[0].len(), 30);
        // Empty input is a shape error, not a panic.
        assert!(exec.execute(&[vec![]]).is_err());
    }

    fn stream_exec(
        n: usize,
        refresh_every: usize,
        slots: usize,
        seed: u64,
    ) -> StreamingFieldExecutor {
        let mut rng = Pcg::seed(seed);
        let tree = generators::random_tree(n, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        StreamingFieldExecutor::new(tfi, &f, 1, refresh_every, slots, 8).unwrap()
    }

    fn set_req(sid: usize, field: &[f32]) -> Vec<f32> {
        let mut r = vec![STREAM_OP_SET, sid as f32];
        r.extend_from_slice(field);
        r
    }

    fn update_req(sid: usize, rows: &[u32], vals: &[f32]) -> Vec<f32> {
        let mut r = vec![STREAM_OP_UPDATE, sid as f32, rows.len() as f32];
        r.extend(rows.iter().map(|&v| v as f32));
        r.extend_from_slice(vals);
        r
    }

    /// Two sessions with different fields: each session's responses
    /// must track its *own* field, including after interleaved updates
    /// — no cross-contamination through the shared tree / plans.
    #[test]
    fn streaming_sessions_do_not_cross_contaminate() {
        let n = 32;
        let exec = stream_exec(n, 4, 4, 11);
        let fa: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
        let fb: Vec<f32> = (0..n).map(|i| -(i as f32) * 0.2).collect();
        let outs = exec.execute(&[set_req(0, &fa), set_req(1, &fb)]).unwrap();
        assert_ne!(outs[0], outs[1]);
        // Interleave updates; session 1's output must stay what a fresh
        // session with the same field history produces.
        let u0 = exec.run_one(&update_req(0, &[3], &[9.0])).unwrap();
        let u1 = exec.run_one(&update_req(1, &[5], &[-7.0])).unwrap();
        assert_ne!(u0, u1);
        let fresh = stream_exec(n, 4, 4, 11); // same tree seed → same metric
        fresh.run_one(&set_req(0, &fb)).unwrap();
        let want = fresh.run_one(&update_req(0, &[5], &[-7.0])).unwrap();
        assert_eq!(u1, want, "session 1 must behave like an isolated session");
    }

    /// Malformed streaming requests fail alone: the session keeps its
    /// state, batch-mates keep their responses, and the worker (here:
    /// the executor) stays serviceable.
    #[test]
    fn streaming_malformed_update_fails_alone_without_poisoning_the_session() {
        let n = 24;
        let exec = stream_exec(n, 0, 2, 12);
        let field: Vec<f32> = (0..n).map(|i| (i as f32 * 0.3).sin()).collect();
        let base = exec.run_one(&set_req(0, &field)).unwrap();
        let bad_cases: Vec<Vec<f32>> = vec![
            vec![], // no header
            vec![3.0, 0.0, 1.0], // unknown opcode
            vec![STREAM_OP_UPDATE, 9.0, 0.0], // unknown session
            update_req(1, &[], &[]), // session never set
            update_req(0, &[24], &[1.0]), // row out of range
            update_req(0, &[0, 1], &[1.0]), // missing values
            vec![STREAM_OP_UPDATE, 0.0, 2.5, 1.0], // fractional row count
            vec![STREAM_OP_REPLAN, 0.0, 0.0, 1.0], // truncated replan (needs u, v, w)
            vec![STREAM_OP_REPLAN, 0.0, 99.0, 0.0, 1.0], // replan vertex out of range
            vec![STREAM_OP_REPLAN, 0.0, 0.0, 1.0, f32::NAN], // replan weight not finite
            vec![STREAM_OP_REPLAN, 1.0, 0.0, 1.0, 2.0], // replan on a never-set session
        ];
        let good = update_req(0, &[2], &[5.0]);
        let mut batch = bad_cases.clone();
        batch.push(good.clone());
        let results = exec.execute_each(&batch);
        for (i, r) in results[..bad_cases.len()].iter().enumerate() {
            assert!(r.is_err(), "malformed request {i} must fail");
        }
        let ok = results.last().unwrap().as_ref().expect("good batch-mate must succeed");
        // The good update saw the *original* session state: none of the
        // malformed requests may have mutated it.
        let fresh = stream_exec(n, 0, 2, 12);
        let fresh_base = fresh.run_one(&set_req(0, &field)).unwrap();
        assert_eq!(base, fresh_base);
        let want = fresh.run_one(&good).unwrap();
        assert_eq!(*ok, want, "failed requests must not have poisoned the session");
    }

    /// A replan request reweights the shared metric in place; the
    /// response must be **bit-identical** to a fresh executor built
    /// over the already-mutated tree (the in-place re-plan's rebuild
    /// equivalence, end to end through the wire protocol).
    #[test]
    fn streaming_replan_requests_reweight_the_shared_metric() {
        let n = 28;
        let mut rng = Pcg::seed(14);
        let tree = generators::random_tree(n, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        let exec = StreamingFieldExecutor::new(tfi, &f, 1, 0, 2, 8).unwrap();
        let field: Vec<f32> = (0..n).map(|i| (i as f32 * 0.2).cos()).collect();
        let base = exec.run_one(&set_req(0, &field)).unwrap();
        let (eu, ev, ew) = tree.edges()[3];
        let w = (ew * 4.0) as f32;
        let got =
            exec.run_one(&[STREAM_OP_REPLAN, 0.0, eu as f32, ev as f32, w].to_vec()).unwrap();
        assert_ne!(got, base, "reweighting an edge must move the output");
        // Replaying the same weight is a no-op returning the same output.
        let again =
            exec.run_one(&[STREAM_OP_REPLAN, 0.0, eu as f32, ev as f32, w].to_vec()).unwrap();
        assert_eq!(got, again, "same-weight replan must be a no-op");
        // Oracle: a fresh executor over the mutated tree.
        let mut mt = tree.clone();
        assert!(mt.set_edge_weight(eu as usize, ev as usize, w as f64).is_some());
        let tfi2 = TreeFieldIntegrator::builder(&mt).threads(1).build().unwrap();
        let exec2 = StreamingFieldExecutor::new(tfi2, &f, 1, 0, 2, 8).unwrap();
        let want = exec2.run_one(&set_req(0, &field)).unwrap();
        assert_eq!(got, want, "post-replan output must match a rebuilt executor bit-for-bit");
    }

    /// End-to-end through the InferenceServer: streaming workers share
    /// one session table, shutdown drains every in-flight update, and
    /// the update-latency percentiles are populated.
    #[test]
    fn streaming_server_drains_updates_and_reports_update_latency() {
        let n = 16;
        let exec = Arc::new(stream_exec(n, 3, 2, 13));
        let metrics = Arc::clone(exec.metrics_registry());
        let factories: Vec<Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>> = (0..2)
            .map(|_| {
                let exec = Arc::clone(&exec);
                Box::new(move || {
                    Box::new(exec) as Box<dyn BatchExecutor>
                }) as Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>
            })
            .collect();
        let server = InferenceServer::start(
            factories,
            BatcherConfig {
                batch_size: 4,
                batch_timeout: Duration::from_millis(1),
                shed_after: None,
            },
            64,
        );
        let field = vec![1.0f32; n];
        server.submit_blocking(set_req(0, &field)).unwrap().wait().unwrap();
        let handles: Vec<_> = (0..20)
            .map(|i| {
                server
                    .submit_blocking(update_req(0, &[(i % n) as u32], &[i as f32]))
                    .unwrap()
            })
            .collect();
        server.shutdown(); // must drain every in-flight update
        let mut ok = 0;
        for h in handles {
            match h.wait() {
                Ok(out) => {
                    assert_eq!(out.len(), n);
                    ok += 1;
                }
                Err(e) => panic!("update lost during shutdown: {e}"),
            }
        }
        assert_eq!(ok, 20);
        let m = metrics.snapshot();
        assert_eq!(m.updates, 20, "every update must be recorded");
        assert!(m.update_p50 > 0.0 && m.update_p50 <= m.update_p95);
        assert!(m.update_p95 <= m.update_p99);
    }

    /// Satellite (deprecation shim): the legacy f32 wire and the typed
    /// wire must produce bit-identical outputs for ops 0/1/2 — the shim
    /// parses into the same enum and runs the same execution path.
    #[test]
    fn legacy_shim_matches_typed_wire_on_ops_0_1_2() {
        let n = 20;
        let mut rng = Pcg::seed(17);
        let tree = generators::random_tree(n, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let build = || {
            let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
            StreamingFieldExecutor::new(tfi, &f, 1, 2, 4, 8).unwrap()
        };
        let legacy = build();
        let typed = build(); // same tree → same metric
        let (eu, ev, _) = tree.edges()[2];
        let field: Vec<f32> = (0..n).map(|i| (i as f32 * 0.15).sin()).collect();
        let via_typed = |exec: &StreamingFieldExecutor, req: StreamRequest, id: u64| {
            let words = protocol::request_words(&req, id);
            let out = exec.run_one(&words).expect("typed request");
            let (got_id, resp) = protocol::response_from_words(&out).expect("typed response");
            assert_eq!(got_id, id, "response must echo the request id");
            match resp {
                StreamResponse::Output { values, .. } => values,
                other => panic!("expected Output, got {other:?}"),
            }
        };
        // op 0: set
        let l = legacy.run_one(&set_req(1, &field)).unwrap();
        let t = via_typed(
            &typed,
            StreamRequest::Set {
                session: 1,
                rows: n as u32,
                channels: 1,
                values: field.clone(),
            },
            100,
        );
        assert_eq!(l, t, "set: shim and typed wire must agree bit-for-bit");
        // op 1: update (legacy infers the width; typed states it)
        let l = legacy.run_one(&update_req(1, &[4, 9], &[2.5, -1.0])).unwrap();
        let t = via_typed(
            &typed,
            StreamRequest::Update {
                session: 1,
                rows: vec![4, 9],
                channels: 1,
                values: vec![2.5, -1.0],
            },
            101,
        );
        assert_eq!(l, t, "update: shim and typed wire must agree bit-for-bit");
        // op 2: replan (the legacy wire carries the weight as f32 —
        // feed the typed path the same f32-rounded weight)
        let l = legacy
            .run_one(&[STREAM_OP_REPLAN, 1.0, eu as f32, ev as f32, 1.5])
            .unwrap();
        let t = via_typed(
            &typed,
            StreamRequest::ReplanEdge {
                session: 1,
                u: eu,
                v: ev,
                w: 1.5f32 as f64,
            },
            102,
        );
        assert_eq!(l, t, "replan: shim and typed wire must agree bit-for-bit");
    }

    /// LRU admission: filling the table evicts the least-recently-used
    /// lease, the victim gets a typed `Rejected { Evicted }`, and a
    /// re-`Set` re-admits it with correct state.
    #[test]
    fn lru_eviction_rejects_typed_and_recovers_on_re_set() {
        let n = 16;
        let exec = stream_exec(n, 0, 2, 18); // capacity 2
        let field: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
        let set = |sid: u32| StreamRequest::Set {
            session: sid,
            rows: n as u32,
            channels: 1,
            values: field.clone(),
        };
        assert!(matches!(exec.execute_request(&set(10)), StreamResponse::Output { .. }));
        assert!(matches!(exec.execute_request(&set(11)), StreamResponse::Output { .. }));
        // Touch 10 so 11 is the LRU victim when 12 arrives.
        assert!(matches!(
            exec.execute_request(&StreamRequest::Lease { session: 10 }),
            StreamResponse::Output { .. }
        ));
        assert!(matches!(exec.execute_request(&set(12)), StreamResponse::Output { .. }));
        assert_eq!(exec.metrics().sessions_evicted, 1);
        match exec.execute_request(&StreamRequest::Update {
            session: 11,
            rows: vec![0],
            channels: 1,
            values: vec![1.0],
        }) {
            StreamResponse::Rejected { reason: RejectReason::Evicted, .. } => {}
            other => panic!("evicted session must be rejected typed, got {other:?}"),
        }
        // Survivors are untouched; the victim recovers via re-Set — and
        // behaves exactly like a session that was never evicted.
        assert!(matches!(
            exec.execute_request(&StreamRequest::Lease { session: 10 }),
            StreamResponse::Output { .. }
        ));
        // Re-Set evicts the current LRU (12) to make room — 11 is live
        // again with fresh state.
        assert!(matches!(exec.execute_request(&set(11)), StreamResponse::Output { .. }));
        let upd = StreamRequest::Update {
            session: 11,
            rows: vec![3],
            channels: 1,
            values: vec![7.0],
        };
        let got = match exec.execute_request(&upd) {
            StreamResponse::Output { values, .. } => values,
            other => panic!("re-admitted session must serve, got {other:?}"),
        };
        let oracle = stream_exec(n, 0, 2, 18);
        assert!(matches!(oracle.execute_request(&set(11)), StreamResponse::Output { .. }));
        let want = match oracle.execute_request(&upd) {
            StreamResponse::Output { values, .. } => values,
            other => panic!("oracle must serve, got {other:?}"),
        };
        assert_eq!(got, want, "re-admitted session must be bit-identical to a fresh one");
    }

    /// The per-session pending bound sheds with `SessionBusy` instead
    /// of queueing without limit, and the close/lease lifecycle is
    /// idempotent.
    #[test]
    fn session_busy_close_and_lease_lifecycle() {
        let n = 16;
        let exec = stream_exec(n, 0, 2, 19).with_max_pending(1);
        let field: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
        let set = StreamRequest::Set { session: 5, rows: n as u32, channels: 1, values: field };
        assert!(matches!(exec.execute_request(&set), StreamResponse::Output { .. }));
        // Saturate the pending counter by hand (as a stalled in-flight
        // update would) — the next update must shed typed.
        {
            let entry = exec.lookup(5).expect("leased");
            entry.pending.fetch_add(1, Ordering::Relaxed);
            match exec.execute_request(&StreamRequest::Update {
                session: 5,
                rows: vec![0],
                channels: 1,
                values: vec![1.0],
            }) {
                StreamResponse::Rejected { reason: RejectReason::SessionBusy, .. } => {}
                other => panic!("saturated session must shed, got {other:?}"),
            }
            entry.pending.fetch_sub(1, Ordering::Relaxed);
        }
        // Back under the bound: updates flow again.
        assert!(matches!(
            exec.execute_request(&StreamRequest::Update {
                session: 5,
                rows: vec![0],
                channels: 1,
                values: vec![1.0],
            }),
            StreamResponse::Output { .. }
        ));
        // Mismatched typed width fails alone.
        match exec.execute_request(&StreamRequest::Update {
            session: 5,
            rows: vec![0],
            channels: 3,
            values: vec![1.0, 2.0, 3.0],
        }) {
            StreamResponse::Error { message } => {
                assert!(message.contains("width"), "got: {message}")
            }
            other => panic!("width mismatch must error, got {other:?}"),
        }
        // Close is idempotent; a closed session is gone (not evicted).
        assert_eq!(
            exec.execute_request(&StreamRequest::Close { session: 5 }),
            StreamResponse::Closed { session: 5 }
        );
        assert_eq!(
            exec.execute_request(&StreamRequest::Close { session: 5 }),
            StreamResponse::Closed { session: 5 }
        );
        match exec.execute_request(&StreamRequest::Lease { session: 5 }) {
            StreamResponse::Error { message } => {
                assert!(message.contains("not initialised"), "got: {message}")
            }
            other => panic!("closed session must read as uninitialised, got {other:?}"),
        }
    }

    fn open_req(sid: u32, n: usize, edges: &[(u32, u32, f64)]) -> StreamRequest {
        StreamRequest::OpenGraph { session: sid, n: n as u32, edges: edges.to_vec() }
    }

    fn tree_for(n: usize, seed: u64) -> crate::tree::Tree {
        let mut rng = Pcg::seed(seed);
        generators::random_tree(n, 0.2, 1.0, &mut rng)
    }

    /// A fresh oracle executor built directly over `tree` with the same
    /// build options the plan cache uses (leaf threshold, one thread).
    fn oracle_over(tree: &crate::tree::Tree) -> StreamingFieldExecutor {
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(tree)
            .leaf_threshold(32)
            .threads(1)
            .build()
            .unwrap();
        StreamingFieldExecutor::new(tfi, &f, 1, 0, 4, 8).unwrap()
    }

    /// `OpenGraph` + `Set` binds a session to a cache-built graph whose
    /// responses are bit-identical to an executor built directly over
    /// that graph; a second open of the same edge list is a cache hit
    /// resolving to the same entry.
    #[test]
    fn open_graph_set_serves_the_cached_graph_bit_exactly() {
        let n = 24;
        let exec = stream_exec(n, 0, 4, 61);
        let t2 = tree_for(n, 62);
        let edges = t2.edges().to_vec();
        match exec.execute_request(&open_req(1, n, &edges)) {
            StreamResponse::Output { session: 1, channels: 0, values, .. } => {
                assert!(values.is_empty(), "the open ack carries no field")
            }
            other => panic!("open must ack with an empty Output, got {other:?}"),
        }
        assert_eq!(exec.metrics().cache_misses, 1);
        assert_eq!(exec.plan_cache().graphs(), 1);
        let field: Vec<f32> = (0..n).map(|i| (i as f32 * 0.15).sin()).collect();
        let set = |sid: u32| StreamRequest::Set {
            session: sid,
            rows: n as u32,
            channels: 1,
            values: field.clone(),
        };
        let got = match exec.execute_request(&set(1)) {
            StreamResponse::Output { values, .. } => values,
            other => panic!("set after open must serve, got {other:?}"),
        };
        let oracle = oracle_over(&t2);
        let want = match oracle.execute_request(&set(1)) {
            StreamResponse::Output { values, .. } => values,
            other => panic!("oracle must serve, got {other:?}"),
        };
        assert_eq!(got, want, "cached-graph output must match a directly built executor");
        // Same edge list again (another session): a hit, not a rebuild.
        assert!(matches!(
            exec.execute_request(&open_req(2, n, &edges)),
            StreamResponse::Output { channels: 0, .. }
        ));
        let m = exec.metrics();
        assert_eq!((m.cache_hits, m.cache_misses), (1, 1));
        assert_eq!(exec.plan_cache().graphs(), 1);
        let got2 = match exec.execute_request(&set(2)) {
            StreamResponse::Output { values, .. } => values,
            other => panic!("second session must serve, got {other:?}"),
        };
        assert_eq!(got2, want, "both sessions ride one cached entry");
        // A session that never opened still serves the default graph.
        assert!(matches!(exec.execute_request(&set(3)), StreamResponse::Output { .. }));
    }

    /// `OpenGraph` on a live same-size session migrates it in place:
    /// the field carries over and the returned output is bit-identical
    /// to a fresh session opened on the target graph with that field.
    #[test]
    fn open_graph_migrates_a_live_session_in_place() {
        let n = 24;
        let exec = stream_exec(n, 0, 4, 63);
        let field: Vec<f32> = (0..n).map(|i| (i as f32 * 0.2).cos()).collect();
        let set = StreamRequest::Set {
            session: 9,
            rows: n as u32,
            channels: 1,
            values: field.clone(),
        };
        assert!(matches!(exec.execute_request(&set), StreamResponse::Output { .. }));
        let t2 = tree_for(n, 64);
        let got = match exec.execute_request(&open_req(9, n, t2.edges())) {
            StreamResponse::Output { channels: 1, values, .. } => values,
            other => panic!("migrating open must return the refreshed output, got {other:?}"),
        };
        let oracle = oracle_over(&t2);
        let want = match oracle.execute_request(&set) {
            StreamResponse::Output { values, .. } => values,
            other => panic!("oracle must serve, got {other:?}"),
        };
        assert_eq!(got, want, "migrated output must match a fresh session on the target");
        // The migrated session keeps serving updates against the new graph.
        let upd = StreamRequest::Update { session: 9, rows: vec![3], channels: 1, values: vec![2.0] };
        let got = match exec.execute_request(&upd) {
            StreamResponse::Output { values, .. } => values,
            other => panic!("post-migration update must serve, got {other:?}"),
        };
        let want = match oracle.execute_request(&upd) {
            StreamResponse::Output { values, .. } => values,
            other => panic!("oracle must serve, got {other:?}"),
        };
        assert_eq!(got, want);
    }

    /// Malformed edge lists fail their frame typed — nothing is cached,
    /// no worker panics (the validation runs before `Tree::from_edges`
    /// ever would).
    #[test]
    fn open_graph_rejects_malformed_edge_lists_typed() {
        let n = 8;
        let exec = stream_exec(n, 0, 4, 65);
        let bad: Vec<(Vec<(u32, u32, f64)>, &str)> = vec![
            (vec![(0, 1, 1.0)], "needs"),                                // wrong count
            (vec![(0, 1, 1.0), (1, 2, 1.0), (0, 2, 1.0)], "cycle"),      // cycle, 3 = n-1 for n=4
            (vec![(0, 0, 1.0), (1, 2, 1.0), (2, 3, 1.0)], "distinct"),   // self-loop
            (vec![(0, 9, 1.0), (1, 2, 1.0), (2, 3, 1.0)], "distinct"),   // out of range
            (vec![(0, 1, f64::NAN), (1, 2, 1.0), (2, 3, 1.0)], "weight"),
            (vec![(0, 1, -1.0), (1, 2, 1.0), (2, 3, 1.0)], "weight"),
        ];
        for (edges, needle) in bad {
            let nv = if edges.len() == 1 { 8 } else { 4 };
            match exec.execute_request(&open_req(1, nv, &edges)) {
                StreamResponse::Error { message } => assert!(
                    message.contains("open-graph") && message.contains(needle),
                    "edges {edges:?}: got message {message:?}"
                ),
                other => panic!("edges {edges:?} must be rejected typed, got {other:?}"),
            }
        }
        let m = exec.metrics();
        assert_eq!((m.cache_hits, m.cache_misses), (0, 0), "rejects never touch the cache");
        assert_eq!(exec.plan_cache().graphs(), 0);
    }

    /// Evicting a graph from the plan cache must not poison sessions
    /// riding it: they keep their `Arc` and keep answering bit-exactly;
    /// only the *cache* forgets the entry (the next open rebuilds it).
    #[test]
    fn cache_eviction_never_poisons_in_flight_sessions() {
        let n = 24;
        let exec = stream_exec(n, 0, 4, 66).with_cache(CacheConfig {
            max_graphs: 1,
            max_bytes_mb: 0,
            fuse_updates: true,
        });
        let ta = tree_for(n, 67);
        let tb = tree_for(n, 68);
        let field: Vec<f32> = (0..n).map(|i| (i as f32 * 0.1).sin()).collect();
        let set = |sid: u32| StreamRequest::Set {
            session: sid,
            rows: n as u32,
            channels: 1,
            values: field.clone(),
        };
        assert!(matches!(
            exec.execute_request(&open_req(1, n, ta.edges())),
            StreamResponse::Output { .. }
        ));
        assert!(matches!(exec.execute_request(&set(1)), StreamResponse::Output { .. }));
        // Opening B evicts A from the single-entry cache…
        assert!(matches!(
            exec.execute_request(&open_req(2, n, tb.edges())),
            StreamResponse::Output { .. }
        ));
        assert!(matches!(exec.execute_request(&set(2)), StreamResponse::Output { .. }));
        let m = exec.metrics();
        assert_eq!(m.cache_evictions, 1);
        assert_eq!(exec.plan_cache().graphs(), 1);
        // …but session 1 still rides A's plans, bit-exactly.
        let upd = StreamRequest::Update { session: 1, rows: vec![5], channels: 1, values: vec![3.0] };
        let got = match exec.execute_request(&upd) {
            StreamResponse::Output { values, .. } => values,
            other => panic!("evicted-graph session must keep serving, got {other:?}"),
        };
        let oracle = oracle_over(&ta);
        assert!(matches!(oracle.execute_request(&set(1)), StreamResponse::Output { .. }));
        let want = match oracle.execute_request(&upd) {
            StreamResponse::Output { values, .. } => values,
            other => panic!("oracle must serve, got {other:?}"),
        };
        assert_eq!(got, want, "eviction must never produce a wrong-graph answer");
        // Re-opening A is a miss (it was evicted) that rebuilds cleanly.
        assert!(matches!(
            exec.execute_request(&open_req(3, n, ta.edges())),
            StreamResponse::Output { channels: 0, .. }
        ));
        assert_eq!(exec.metrics().cache_misses, 3);
    }

    /// A batch window of same-session updates fuses into one delta pass
    /// whose post-window state is bit-identical to unfused serving, and
    /// the fusion counters record the saved work. Fused members are all
    /// answered with the post-window output (within-batch ordering is
    /// unspecified on this wire), so the comparison anchors on the last
    /// member and the leased session state.
    #[test]
    fn fused_batch_window_matches_unfused_serving() {
        let n = 20;
        let fused = stream_exec(n, 3, 4, 69);
        let unfused = stream_exec(n, 3, 4, 69).with_cache(CacheConfig {
            max_graphs: 8,
            max_bytes_mb: 0,
            fuse_updates: false,
        });
        let field: Vec<f32> = (0..n).map(|i| (i as f32 * 0.15).sin()).collect();
        let window: Vec<Vec<f32>> = vec![
            update_req(0, &[2, 5], &[1.0, -2.0]),
            update_req(0, &[5], &[4.0]),
            update_req(0, &[11, 2, 11], &[0.5, 1.5, -0.5]),
        ];
        for exec in [&fused, &unfused] {
            exec.run_one(&set_req(0, &field)).unwrap();
        }
        let rf = fused.execute_each(&window);
        let ru = unfused.execute_each(&window);
        assert!(rf.iter().all(|r| r.is_ok()) && ru.iter().all(|r| r.is_ok()));
        let last_u = ru.last().unwrap().as_ref().unwrap();
        for (i, r) in rf.iter().enumerate() {
            assert_eq!(
                r.as_ref().unwrap(),
                last_u,
                "member {i}: fused responses carry the post-window output"
            );
        }
        // The leased state agrees bit-for-bit.
        let lease = StreamRequest::Lease { session: 0 };
        let (a, b) = match (fused.execute_request(&lease), unfused.execute_request(&lease)) {
            (
                StreamResponse::Output { values: a, .. },
                StreamResponse::Output { values: b, .. },
            ) => (a, b),
            other => panic!("lease must serve, got {other:?}"),
        };
        assert_eq!(a, b, "fused and unfused sessions must hold identical state");
        let mf = fused.metrics();
        assert_eq!(mf.fused_updates, 3);
        assert!(mf.fusion_rows_saved >= 2, "got {}", mf.fusion_rows_saved);
        assert_eq!(mf.updates, 3, "every member records an update latency");
        let mu = unfused.metrics();
        assert_eq!((mu.fused_updates, mu.fusion_rows_saved), (0, 0));
        // A later single update keeps both sessions in lockstep (the
        // cadence counters advanced identically through the window).
        let tail = update_req(0, &[7], &[9.0]);
        let tf = fused.run_one(&tail).unwrap();
        let tu = unfused.run_one(&tail).unwrap();
        assert_eq!(tf, tu, "refresh cadence must fire identically after a fused window");
    }

    /// Ensemble serving path: the generic executor over an
    /// [`EnsembleFieldIntegrator`] shares the ensemble's pool, fans
    /// batches out, and isolates per-request failures.
    #[test]
    fn ensemble_executor_batch_fanout_and_error_isolation() {
        use crate::ftfi::ensemble::EnsembleFieldIntegrator;
        let mut rng = Pcg::seed(21);
        let g = generators::path_plus_random_edges(30, 15, &mut rng);
        let ens = EnsembleFieldIntegrator::builder(&g).trees(3).seed(5).build().unwrap();
        let shared = Arc::clone(ens.pool());
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let exec = FieldExecutor::new(ens, f, 4);
        assert!(
            Arc::ptr_eq(&exec.pool, &shared),
            "executor must reuse the ensemble's pool (one thread budget)"
        );
        let good = vec![1.0f32; 30];
        let bad = vec![1.0f32; 7];
        let results = exec.execute_each(&[good.clone(), bad, good]);
        assert_eq!(results.len(), 3);
        assert!(results[0].is_ok());
        match &results[1] {
            Err(e) => assert!(e.contains("shape mismatch"), "{e}"),
            Ok(_) => panic!("malformed request must fail alone"),
        }
        assert!(results[2].is_ok(), "batch-mates must not be poisoned");
        assert_eq!(results[0].as_ref().unwrap(), results[2].as_ref().unwrap());
    }

    /// Ensemble serving path: fixed `(seed, trees)` responses are
    /// bit-identical across thread counts (the CI thread matrix runs
    /// the whole suite under `FTFI_THREADS ∈ {1, 4}`; the explicit
    /// `.threads(..)` knobs pin both engines regardless).
    #[test]
    fn ensemble_executor_is_seed_deterministic_across_thread_counts() {
        use crate::ftfi::ensemble::EnsembleFieldIntegrator;
        let mut rng = Pcg::seed(22);
        // n ≥ 256 so both the batch fan-out and the tree axis engage.
        let g = generators::path_plus_random_edges(300, 150, &mut rng);
        let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
        let build = |threads: usize| {
            let b = EnsembleFieldIntegrator::builder(&g).trees(3).seed(9).threads(threads);
            b.build().unwrap()
        };
        let exec_s = FieldExecutor::new(build(1), f.clone(), 8);
        let exec_p = FieldExecutor::new(build(4), f, 8);
        let inputs: Vec<Vec<f32>> = (0..4)
            .map(|k| (0..300).map(|i| ((i + 97 * k) as f32 * 0.01).sin()).collect())
            .collect();
        let a = exec_s.execute_each(&inputs);
        let b = exec_p.execute_each(&inputs);
        assert_eq!(a.len(), b.len());
        for (i, (ra, rb)) in a.iter().zip(&b).enumerate() {
            let (ra, rb) = (ra.as_ref().unwrap(), rb.as_ref().unwrap());
            assert_eq!(ra, rb, "request {i}: ensemble response must be bit-identical");
        }
    }
}
