//! Field-integration serving: a [`BatchExecutor`] that answers
//! `Σ_u f(dist(v,u))·x[u]` requests over a fixed metric, plugging the
//! FTFI stack into the coordinator's queue/batcher/worker machinery.
//!
//! Three flavours:
//!
//! - [`FieldExecutor`] runs any [`FieldIntegrator`] backend (tree,
//!   MST-of-graph, brute reference) — one planning pass per request.
//! - [`PreparedFieldExecutor`] owns a [`TreeFieldIntegrator`] plus the
//!   [`PreparedPlans`] for one `f`, so every request reuses the frozen
//!   cross-block plans — the "build once, integrate any number of
//!   fields" serving pattern of §3.1/§3.2.
//! - [`StreamingFieldExecutor`] serves the *online* workload: stateful
//!   per-session [`StreamingIntegrator`]s behind one shared tree / plan
//!   set, answering sparse `apply_update` requests through the delta
//!   fast path (wire protocol below) with per-update latency
//!   percentiles in the [`MetricsRegistry`].
//!
//! Error contract: every [`FtfiError`] (shape mismatches above all) is
//! stringified into a per-request `Err(String)` via
//! [`BatchExecutor::execute_each`], which the batcher delivers as
//! `ServerError::Exec` to that request alone — a malformed request
//! fails its own response without poisoning its batch-mates, and can
//! never panic a worker thread.
//!
//! Both executors fan fused batches out across a [`WorkPool`] — the
//! serving batch axis — so one worker drives all cores of its budget.
//! Responses keep their request order and stay bit-identical to serial
//! execution (the pool's determinism contract). Share one pool across
//! workers (builder `.pool(..)` / [`FieldExecutor::with_pool`]) to bound
//! the process-wide thread count.

use super::batcher::BatchExecutor;
use super::metrics::{MetricsRegistry, MetricsSnapshot};
use super::protocol::{self, RejectReason, StreamRequest, StreamResponse};
use crate::ftfi::functions::FDist;
use crate::ftfi::streaming::{SharedPlans, StreamingIntegrator};
use crate::ftfi::{FieldIntegrator, FtfiError, TreeFieldIntegrator};
use crate::linalg::lanes::Precision;
use crate::linalg::matrix::Matrix;
use crate::runtime::pool::{WorkPool, PAR_MAP_MIN_N};
// Session locks come from the crate-wide sync shim so loom can model the
// set-vs-update race; Arc deliberately stays `std` (see `crate::sync`).
use crate::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use crate::sync::Mutex;
use crate::tree::integrator_tree::PreparedPlans;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Instant;

/// Decode one flattened request into an `n×d` field (row-major, rows
/// indexed by vertex id). The request length must be a non-zero
/// multiple of `n`.
fn decode(input: &[f32], n: usize) -> Result<Matrix, FtfiError> {
    if input.is_empty() || n == 0 || input.len() % n != 0 {
        return Err(FtfiError::ShapeMismatch { expected: n, got: input.len() });
    }
    let d = input.len() / n;
    Ok(Matrix::from_vec(n, d, input.iter().map(|&v| v as f64).collect()))
}

fn encode(m: Matrix) -> Vec<f32> {
    m.data().iter().map(|&v| v as f32).collect()
}

/// Serve integrations of a fixed `f` through any [`FieldIntegrator`]
/// backend. `I: Sync` because fused batches fan out across the pool's
/// threads (every integrator in this crate is `Sync`).
pub struct FieldExecutor<I: FieldIntegrator + Sync + 'static> {
    integrator: I,
    f: FDist,
    max_batch: usize,
    pool: Arc<WorkPool>,
}

impl<I: FieldIntegrator + Sync + 'static> FieldExecutor<I> {
    /// Build reusing the integrator's own work pool when it has one
    /// (so the batch fan-out and the integrator's internal forks share
    /// one thread budget), else an auto-sized pool (`FTFI_THREADS`,
    /// else all cores).
    pub fn new(integrator: I, f: FDist, max_batch: usize) -> Self {
        let pool = integrator
            .work_pool()
            .cloned()
            .unwrap_or_else(|| Arc::new(WorkPool::with_auto(0)));
        Self::with_pool(integrator, f, max_batch, pool)
    }

    /// Build over a shared work pool (bounds the process-wide thread
    /// budget when several workers serve side by side).
    pub fn with_pool(integrator: I, f: FDist, max_batch: usize, pool: Arc<WorkPool>) -> Self {
        FieldExecutor { integrator, f, max_batch: max_batch.max(1), pool }
    }

    fn run_one(&self, input: &[f32]) -> Result<Vec<f32>, String> {
        let x = decode(input, self.integrator.n()).map_err(|e| e.to_string())?;
        let out = self.integrator.integrate(&self.f, &x).map_err(|e| e.to_string())?;
        Ok(encode(out))
    }
}

impl<I: FieldIntegrator + Sync + 'static> BatchExecutor for FieldExecutor<I> {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        self.execute_each(inputs).into_iter().collect()
    }

    /// Requests fail independently: a malformed request gets its own
    /// `Err` while its batch-mates still succeed. Requests fan out
    /// across the work pool (unless the metric is too small to justify
    /// helper threads); responses keep the request order.
    fn execute_each(&self, inputs: &[Vec<f32>]) -> Vec<Result<Vec<f32>, String>> {
        if self.integrator.n() < PAR_MAP_MIN_N {
            return inputs.iter().map(|input| self.run_one(input)).collect();
        }
        self.pool.map(inputs, |_, input| self.run_one(input))
    }
}

/// Serve integrations of a fixed `f` with prepared plans: the Chebyshev
/// expansions / lattice FFT tables / separable decompositions are built
/// once at construction and reused for every request.
pub struct PreparedFieldExecutor {
    tfi: TreeFieldIntegrator,
    plans: PreparedPlans,
    max_batch: usize,
}

impl PreparedFieldExecutor {
    /// Freeze `f` (with a `channels` width hint for the planner) into a
    /// serving executor. Fails with a typed [`FtfiError`] — e.g. a
    /// forced-but-inapplicable strategy in the integrator's policy —
    /// instead of panicking inside a worker thread later.
    pub fn new(
        tfi: TreeFieldIntegrator,
        f: &FDist,
        channels: usize,
        max_batch: usize,
    ) -> Result<Self, FtfiError> {
        let plans = tfi.prepare_plans(f, channels)?;
        Ok(PreparedFieldExecutor { tfi, plans, max_batch: max_batch.max(1) })
    }

    /// Number of vertices a request row must cover.
    pub fn n(&self) -> usize {
        self.tfi.n()
    }

    fn run_one(&self, input: &[f32]) -> Result<Vec<f32>, String> {
        let x = decode(input, self.tfi.n()).map_err(|e| e.to_string())?;
        let out = self.tfi.integrate_prepared(&x, &self.plans).map_err(|e| e.to_string())?;
        Ok(encode(out))
    }
}

impl BatchExecutor for PreparedFieldExecutor {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        self.execute_each(inputs).into_iter().collect()
    }

    /// Requests fail independently: a malformed request gets its own
    /// `Err` while its batch-mates still succeed. Requests fan out
    /// across the integrator's work pool (set per builder via
    /// `.threads(..)` / `.pool(..)`) unless the metric is too small to
    /// justify helper threads; responses keep the request order.
    fn execute_each(&self, inputs: &[Vec<f32>]) -> Vec<Result<Vec<f32>, String>> {
        if self.tfi.n() < PAR_MAP_MIN_N {
            return inputs.iter().map(|input| self.run_one(input)).collect();
        }
        self.tfi.pool().map(inputs, |_, input| self.run_one(input))
    }
}

/// Opcode of a streaming request (`input[0]`): install/overwrite a
/// session's full field.
pub const STREAM_OP_SET: f32 = 0.0;
/// Opcode of a streaming request (`input[0]`): sparse row update.
pub const STREAM_OP_UPDATE: f32 = 1.0;
/// Opcode of a streaming request (`input[0]`): reweight one tree edge
/// of the shared metric (every session sees the change).
pub const STREAM_OP_REPLAN: f32 = 2.0;

/// Default bound on concurrently in-flight updates per session before
/// admission control answers `Rejected { SessionBusy }`.
pub const DEFAULT_MAX_PENDING: usize = 32;

/// One leased session: the integrator behind its serialising mutex,
/// plus the admission-control state (in-flight counter, LRU stamp).
struct SessionEntry {
    cell: Mutex<StreamingIntegrator>,
    pending: AtomicUsize,
    last_used: AtomicU64,
}

/// Serve the streaming/online workload: per-session
/// [`StreamingIntegrator`]s sharing one tree, one frozen plan set and
/// one work pool. Requests ride the coordinator's `Vec<f32>` queue in
/// one of two encodings, told apart by the first word:
///
/// - **Typed** ([`protocol`]): a NaN-boxed frame payload carrying a
///   [`StreamRequest`] (`Set`/`Update`/`ReplanEdge`/`Close`/`Lease`);
///   the response is a [`StreamResponse`] frame with the request's id
///   echoed. Decode failures return `Err("protocol: …")`, which the
///   server boundary maps to `ServerError::Protocol` — the frame fails
///   alone.
/// - **Legacy** (`[op, session, …]` f32, the `--wire legacy` shim):
///   parsed into the same typed enum by [`protocol::legacy_to_request`]
///   at this boundary, answered with the bare `n·d` output vector the
///   old wire promised.
///
/// **Admission control**: sessions are *leased* entries in a
/// `max_sessions`-bounded table keyed by client-chosen `u32` ids. A
/// `Set` for a new id evicts the least-recently-used lease when the
/// table is full (the victim's later requests get a typed
/// `Rejected { Evicted }` until it re-`Set`s — the evicted-id ledger
/// holds one entry per distinct evicted id and is cleared by re-`Set`
/// or `Close`). Per-session in-flight updates are bounded by
/// `max_pending`; excess gets `Rejected { SessionBusy }`.
///
/// Updates run the sparse delta fast path with the session's
/// `refresh_every` drift policy; replans reweight one edge of the
/// *shared* metric in place (the O(log n) in-place re-plan, see
/// DESIGN.md "Dynamic graphs & edge re-plans") — the issuing session's
/// output is refreshed eagerly and returned, sibling sessions refresh
/// lazily on their next request. A malformed request (unknown
/// opcode/session, bad row, non-tree edge, bad weight, shape mismatch)
/// fails alone — the session keeps its state, the shared plans stay
/// untouched, and batch-mates keep their responses. Sessions are
/// `Mutex`-guarded, so concurrent batch fan-out over *different*
/// sessions parallelises while same-session updates serialise (arrival
/// order within one fused batch is unspecified — clients that need
/// ordering submit one in-flight update per session). Lock ordering:
/// session table before evicted ledger, session mutex before the shared
/// plan lock (never the reverse), so update/replan/evict interleavings
/// cannot deadlock.
pub struct StreamingFieldExecutor {
    shared: Arc<SharedPlans>,
    /// Cached from the integrator at construction (the integrator now
    /// lives inside the plan cell; these never change afterwards).
    n: usize,
    precision: Precision,
    pool: Arc<WorkPool>,
    refresh_every: usize,
    max_batch: usize,
    capacity: usize,
    max_pending: usize,
    sessions: Mutex<BTreeMap<u32, Arc<SessionEntry>>>,
    evicted: Mutex<BTreeSet<u32>>,
    clock: AtomicU64,
    metrics: Arc<MetricsRegistry>,
}

impl StreamingFieldExecutor {
    /// Freeze `f` (with a `channels` planner hint) and allocate
    /// `max_sessions` empty session slots. `refresh_every` is the drift
    /// policy every session is opened with (`0` = delta-only).
    pub fn new(
        tfi: TreeFieldIntegrator,
        f: &FDist,
        channels: usize,
        refresh_every: usize,
        max_sessions: usize,
        max_batch: usize,
    ) -> Result<Self, FtfiError> {
        let plans = tfi.prepare_plans(f, channels)?;
        let n = tfi.n();
        let precision = plans.precision();
        let pool = Arc::clone(tfi.pool());
        Ok(StreamingFieldExecutor {
            shared: Arc::new(SharedPlans::new(tfi, plans)),
            n,
            precision,
            pool,
            refresh_every,
            max_batch: max_batch.max(1),
            capacity: max_sessions.max(1),
            max_pending: DEFAULT_MAX_PENDING,
            sessions: Mutex::new(BTreeMap::new()),
            evicted: Mutex::new(BTreeSet::new()),
            clock: AtomicU64::new(0),
            metrics: Arc::new(MetricsRegistry::new()),
        })
    }

    /// Bound the per-session in-flight update count (admission control;
    /// 0 is clamped to 1 — a session that can never accept an update
    /// could never serve).
    pub fn with_max_pending(mut self, max_pending: usize) -> Self {
        self.max_pending = max_pending.max(1);
        self
    }

    /// Record into a caller-provided registry (share it with the
    /// server via `InferenceServer::start_with_metrics`, so evictions
    /// and decode failures land in the snapshot the server reports).
    pub fn with_metrics(mut self, metrics: Arc<MetricsRegistry>) -> Self {
        self.metrics = metrics;
        self
    }

    /// Number of vertices a session field must cover.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Session lease capacity.
    pub fn max_sessions(&self) -> usize {
        self.capacity
    }

    /// The serving tier inherited from the integrator at plan-freeze
    /// time (`TreeFieldIntegratorBuilder::precision`): every session's
    /// full integrations, delta updates and refreshes run this tier.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Update-latency percentiles and counters (the streaming SLO);
    /// share the registry with a dashboard via
    /// [`StreamingFieldExecutor::metrics_registry`].
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// The executor's metrics registry (update-latency histogram).
    pub fn metrics_registry(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    /// Advance the LRU clock and stamp `entry` as just-used.
    fn bump(&self, entry: &SessionEntry) {
        let t = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
        entry.last_used.store(t, Ordering::Relaxed);
    }

    /// Resolve a session id to its leased entry, or the typed response
    /// explaining why it has none (`Rejected { Evicted }` for victims
    /// of LRU pressure, an `Error` for ids never `Set`). Table-lock
    /// poisoning is recovered — the map structure is always valid.
    fn lookup(&self, session: u32) -> Result<Arc<SessionEntry>, StreamResponse> {
        let table = self.sessions.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(entry) = table.get(&session) {
            let entry = Arc::clone(entry);
            drop(table);
            self.bump(&entry);
            return Ok(entry);
        }
        drop(table);
        let evicted = self.evicted.lock().unwrap_or_else(|e| e.into_inner());
        if evicted.contains(&session) {
            Err(StreamResponse::Rejected {
                reason: RejectReason::Evicted,
                retry_after_hint_ms: 1,
            })
        } else {
            Err(StreamResponse::Error {
                message: format!("session {session} not initialised (send a set request first)"),
            })
        }
    }

    /// Execute one typed request against the session table. Every
    /// outcome is a typed response — this method never panics and never
    /// poisons a session on a failed request.
    pub fn execute_request(&self, req: &StreamRequest) -> StreamResponse {
        match req {
            StreamRequest::Set { session, rows, channels, values } => {
                self.exec_set(*session, *rows, *channels, values)
            }
            StreamRequest::Update { session, rows, channels, values } => {
                let t0 = Instant::now();
                let resp = self.exec_update(*session, rows, *channels, values);
                if matches!(resp, StreamResponse::Output { .. }) {
                    self.metrics.record_update_latency(t0.elapsed().as_secs_f64());
                }
                resp
            }
            StreamRequest::ReplanEdge { session, u, v, w } => {
                self.exec_replan(*session, *u, *v, *w)
            }
            StreamRequest::Close { session } => self.exec_close(*session),
            StreamRequest::Lease { session } => self.exec_lease(*session),
        }
    }

    fn exec_set(&self, session: u32, rows: u32, channels: u32, values: &[f32]) -> StreamResponse {
        let n = self.n;
        if rows as usize != n || channels == 0 {
            return StreamResponse::Error {
                message: FtfiError::ShapeMismatch { expected: n, got: values.len() }.to_string(),
            };
        }
        let d = channels as usize;
        let field = Matrix::from_vec(n, d, values.iter().map(|&v| v as f64).collect());
        let integ =
            match StreamingIntegrator::new(Arc::clone(&self.shared), field, self.refresh_every) {
                Ok(s) => s,
                Err(e) => return StreamResponse::Error { message: e.to_string() },
            };
        let out: Vec<f32> = integ.output().data().iter().map(|&v| v as f32).collect();
        let mut table = self.sessions.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(entry) = table.get(&session) {
            // Re-`Set` of a live lease: swap the integrator in place so
            // concurrent same-session requests stay serialised.
            let entry = Arc::clone(entry);
            drop(table);
            match entry.cell.lock() {
                Ok(mut cell) => *cell = integ,
                Err(_) => {
                    return StreamResponse::Error {
                        message: format!("session {session} poisoned by an earlier panic"),
                    }
                }
            }
            self.bump(&entry);
        } else {
            if table.len() >= self.capacity {
                // LRU eviction: the victim's id moves to the evicted
                // ledger so its later requests get a typed rejection.
                let victim = table
                    .iter()
                    .min_by_key(|(_, e)| e.last_used.load(Ordering::Relaxed))
                    .map(|(&id, _)| id);
                if let Some(victim) = victim {
                    table.remove(&victim);
                    self.evicted.lock().unwrap_or_else(|e| e.into_inner()).insert(victim);
                    self.metrics.record_eviction();
                }
            }
            let entry = Arc::new(SessionEntry {
                cell: Mutex::new(integ),
                pending: AtomicUsize::new(0),
                last_used: AtomicU64::new(0),
            });
            self.bump(&entry);
            table.insert(session, entry);
            drop(table);
            // A re-`Set` re-admits a previously evicted id.
            self.evicted.lock().unwrap_or_else(|e| e.into_inner()).remove(&session);
        }
        StreamResponse::Output { session, rows, channels, values: out }
    }

    fn exec_update(
        &self,
        session: u32,
        rows: &[u32],
        channels: u32,
        values: &[f32],
    ) -> StreamResponse {
        let entry = match self.lookup(session) {
            Ok(e) => e,
            Err(resp) => return resp,
        };
        // Bounded per-session in-flight updates: the counter spans the
        // cell-lock wait, so a flooded session sheds instead of growing
        // an unbounded convoy on its mutex.
        if entry.pending.fetch_add(1, Ordering::Relaxed) >= self.max_pending {
            entry.pending.fetch_sub(1, Ordering::Relaxed);
            return StreamResponse::Rejected {
                reason: RejectReason::SessionBusy,
                retry_after_hint_ms: 2,
            };
        }
        let resp = self.exec_update_locked(&entry, session, rows, channels, values);
        entry.pending.fetch_sub(1, Ordering::Relaxed);
        resp
    }

    fn exec_update_locked(
        &self,
        entry: &SessionEntry,
        session: u32,
        rows: &[u32],
        channels: u32,
        values: &[f32],
    ) -> StreamResponse {
        let n = self.n;
        for &r in rows {
            if r as usize >= n {
                return StreamResponse::Error {
                    message: format!("row {r} invalid (expected an integer in 0..{n})"),
                };
            }
        }
        let mut cell = match entry.cell.lock() {
            Ok(c) => c,
            Err(_) => {
                return StreamResponse::Error {
                    message: format!("session {session} poisoned by an earlier panic"),
                }
            }
        };
        let d = cell.channels();
        // channels = 0 is the legacy shim's "infer from the session";
        // a typed non-zero width must match the lease it addresses.
        if channels != 0 && channels as usize != d {
            return StreamResponse::Error {
                message: format!("update width {channels} does not match the session's {d}"),
            };
        }
        let k = rows.len();
        if values.len() != k * d {
            return StreamResponse::Error {
                message: FtfiError::ShapeMismatch { expected: k * d, got: values.len() }
                    .to_string(),
            };
        }
        let vm = Matrix::from_vec(k, d, values.iter().map(|&v| v as f64).collect());
        match cell.apply_update(rows, &vm) {
            Ok(out) => StreamResponse::Output {
                session,
                rows: n as u32,
                channels: d as u32,
                values: out.data().iter().map(|&v| v as f32).collect(),
            },
            Err(e) => StreamResponse::Error { message: e.to_string() },
        }
    }

    /// Reweight the tree edge `{u, v}` to `w`. The session mutex is
    /// taken *before* the shared plan lock (the crate-wide lock order);
    /// validation failures surface as this request's typed error with
    /// the plans and every session untouched.
    fn exec_replan(&self, session: u32, u: u32, v: u32, w: f64) -> StreamResponse {
        let n = self.n;
        if u as usize >= n || v as usize >= n {
            return StreamResponse::Error {
                message: format!("vertex invalid (expected an integer in 0..{n})"),
            };
        }
        let entry = match self.lookup(session) {
            Ok(e) => e,
            Err(resp) => return resp,
        };
        let mut cell = match entry.cell.lock() {
            Ok(c) => c,
            Err(_) => {
                return StreamResponse::Error {
                    message: format!("session {session} poisoned by an earlier panic"),
                }
            }
        };
        if let Err(e) = cell.update_edge(u as usize, v as usize, w) {
            return StreamResponse::Error { message: e.to_string() };
        }
        StreamResponse::Output {
            session,
            rows: n as u32,
            channels: cell.channels() as u32,
            values: cell.output().data().iter().map(|&v| v as f32).collect(),
        }
    }

    /// Release a lease. Idempotent: closing an unknown or already
    /// evicted id still acknowledges with `Closed`.
    fn exec_close(&self, session: u32) -> StreamResponse {
        self.sessions.lock().unwrap_or_else(|e| e.into_inner()).remove(&session);
        self.evicted.lock().unwrap_or_else(|e| e.into_inner()).remove(&session);
        StreamResponse::Closed { session }
    }

    /// Touch a lease and return its current (possibly lazily-stale)
    /// output.
    fn exec_lease(&self, session: u32) -> StreamResponse {
        let entry = match self.lookup(session) {
            Ok(e) => e,
            Err(resp) => return resp,
        };
        let cell = match entry.cell.lock() {
            Ok(c) => c,
            Err(_) => {
                return StreamResponse::Error {
                    message: format!("session {session} poisoned by an earlier panic"),
                }
            }
        };
        StreamResponse::Output {
            session,
            rows: self.n as u32,
            channels: cell.channels() as u32,
            values: cell.output().data().iter().map(|&v| v as f32).collect(),
        }
    }

    /// One queue request, either encoding. Typed frames answer with
    /// typed response frames (decode failures become `protocol:`-tagged
    /// errors); legacy frames answer with the bare output vector the
    /// old wire promised.
    fn run_one(&self, input: &[f32]) -> Result<Vec<f32>, String> {
        if protocol::is_typed_words(input) {
            let (req_id, req) = protocol::words_to_payload(input)
                .and_then(|payload| protocol::decode_request(&payload))
                .map_err(|e| {
                    self.metrics.record_protocol_error();
                    format!("{}{e}", protocol::ERR_PROTOCOL_PREFIX)
                })?;
            let resp = self.execute_request(&req);
            Ok(protocol::payload_to_words(&protocol::encode_response(&resp, req_id)))
        } else {
            let req = protocol::legacy_to_request(input, self.n)?;
            match self.execute_request(&req) {
                StreamResponse::Output { values, .. } => Ok(values),
                StreamResponse::Closed { .. } => Ok(Vec::new()),
                StreamResponse::Rejected { reason, .. } => Err(format!("rejected: {reason:?}")),
                StreamResponse::Error { message } => Err(message),
            }
        }
    }
}

impl BatchExecutor for StreamingFieldExecutor {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        self.execute_each(inputs).into_iter().collect()
    }

    /// Requests fail independently and fan out across the integrator's
    /// pool; per-session mutexes serialise same-session updates while
    /// distinct sessions proceed in parallel.
    fn execute_each(&self, inputs: &[Vec<f32>]) -> Vec<Result<Vec<f32>, String>> {
        if self.n < PAR_MAP_MIN_N {
            return inputs.iter().map(|input| self.run_one(input)).collect();
        }
        self.pool.map(inputs, |_, input| self.run_one(input))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, InferenceServer, ServerError};
    use crate::ftfi::brute::btfi;
    use crate::graph::generators;
    use crate::ml::rng::Pcg;
    use std::time::Duration;

    #[test]
    fn prepared_executor_serves_correct_integrals() {
        let mut rng = Pcg::seed(1);
        let tree = generators::random_tree(40, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
        let exec = PreparedFieldExecutor::new(tfi, &f, 1, 8).unwrap();
        let x: Vec<f32> = (0..40).map(|i| (i as f32 * 0.1).sin()).collect();
        let out = exec.execute(&[x.clone()]).unwrap();
        let xm = Matrix::from_vec(40, 1, x.iter().map(|&v| v as f64).collect());
        let want = btfi(&tree, &f, &xm);
        for (got, w) in out[0].iter().zip(want.data()) {
            assert!((*got as f64 - w).abs() < 1e-4 * (1.0 + w.abs()), "{got} vs {w}");
        }
    }

    /// The executor's request loop runs on the workspace hot path
    /// (`integrate_prepared`): responses must stay bit-identical to the
    /// legacy per-node-allocation reference, and repeated requests must
    /// reuse the plan's workspaces without leaking state across them.
    #[test]
    fn prepared_executor_serves_the_workspace_hot_path() {
        let mut rng = Pcg::seed(7);
        let tree = generators::random_tree(120, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        let ref_tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        // Same tree → same IT shape, but plans are instance-pinned:
        // build the reference plans on the reference integrator.
        let ref_plans = ref_tfi.prepare_plans(&f, 1).unwrap();
        let exec = PreparedFieldExecutor::new(tfi, &f, 1, 8).unwrap();
        for k in 0..3 {
            let input: Vec<f32> = (0..120).map(|i| ((i + 31 * k) as f32 * 0.05).sin()).collect();
            let got = exec.run_one(&input).unwrap();
            let x = decode(&input, 120).unwrap();
            let want = encode(ref_tfi.integrate_prepared_legacy(&x, &ref_plans).unwrap());
            assert_eq!(got, want, "request {k}: served response must match the legacy path");
        }
    }

    #[test]
    fn malformed_request_maps_to_exec_error_without_killing_workers() {
        let mut rng = Pcg::seed(2);
        let tree = generators::random_tree(24, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let server = InferenceServer::start(
            vec![Box::new(move || {
                let tfi = TreeFieldIntegrator::builder(&tree).build().expect("valid tree");
                Box::new(PreparedFieldExecutor::new(tfi, &f, 1, 4).expect("plannable f"))
                    as Box<dyn BatchExecutor>
            })],
            BatcherConfig {
                batch_size: 1,
                batch_timeout: Duration::from_millis(1),
                shed_after: None,
            },
            64,
        );
        // Wrong-length field: must come back as ServerError::Exec (the
        // FtfiError::ShapeMismatch string), not crash the worker.
        let bad = server.submit_blocking(vec![1.0f32; 7]).unwrap();
        match bad.wait() {
            Err(ServerError::Exec(msg)) => {
                assert!(msg.contains("shape mismatch"), "unexpected message: {msg}")
            }
            other => panic!("expected Exec error, got {other:?}"),
        }
        // The worker survived: a well-formed request still succeeds.
        let good = server.submit_blocking(vec![1.0f32; 24]).unwrap();
        let out = good.wait().expect("worker should still be alive");
        assert_eq!(out.len(), 24);
        server.shutdown();
    }

    #[test]
    fn malformed_request_fails_alone_inside_a_batch() {
        let mut rng = Pcg::seed(4);
        let tree = generators::random_tree(16, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.3, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
        let exec = PreparedFieldExecutor::new(tfi, &f, 1, 4).unwrap();
        let good = vec![1.0f32; 16];
        let bad = vec![1.0f32; 7];
        let results = exec.execute_each(&[good.clone(), bad, good]);
        assert_eq!(results.len(), 3);
        assert!(results[0].is_ok());
        match &results[1] {
            Err(e) => assert!(e.contains("shape mismatch"), "{e}"),
            Ok(_) => panic!("malformed request must fail"),
        }
        assert!(results[2].is_ok(), "batch-mates must not be poisoned");
    }

    #[test]
    fn parallel_execute_each_is_ordered_and_bit_identical_to_serial() {
        let mut rng = Pcg::seed(5);
        let tree = generators::random_tree(700, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
        let serial = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        let par = TreeFieldIntegrator::builder(&tree).threads(4).build().unwrap();
        let exec_s = PreparedFieldExecutor::new(serial, &f, 1, 8).unwrap();
        let exec_p = PreparedFieldExecutor::new(par, &f, 1, 8).unwrap();
        let inputs: Vec<Vec<f32>> = (0..6)
            .map(|k| (0..700).map(|i| ((i + 137 * k) as f32 * 0.01).sin()).collect())
            .collect();
        let a = exec_s.execute_each(&inputs);
        let b = exec_p.execute_each(&inputs);
        assert_eq!(a.len(), b.len());
        for (i, (ra, rb)) in a.iter().zip(&b).enumerate() {
            let (ra, rb) = (ra.as_ref().unwrap(), rb.as_ref().unwrap());
            assert_eq!(ra, rb, "request {i}: parallel response must be bit-identical");
        }
    }

    /// One thread budget end to end: the generic executor must reuse the
    /// integrator's pool rather than stacking a second auto-sized one.
    #[test]
    fn generic_executor_reuses_the_integrator_pool() {
        use crate::ftfi::GraphFieldIntegrator;
        let mut rng = Pcg::seed(6);
        let g = generators::path_plus_random_edges(20, 10, &mut rng);
        let gfi = GraphFieldIntegrator::builder(&g).threads(3).build().unwrap();
        let shared = Arc::clone(gfi.tree_integrator().pool());
        let exec = FieldExecutor::new(gfi, FDist::Identity, 4);
        assert!(Arc::ptr_eq(&exec.pool, &shared), "executor must reuse the integrator's pool");
        assert_eq!(exec.pool.threads(), 3);
    }

    #[test]
    fn generic_executor_works_over_any_backend() {
        use crate::ftfi::GraphFieldIntegrator;
        let mut rng = Pcg::seed(3);
        let g = generators::path_plus_random_edges(30, 15, &mut rng);
        let gfi = GraphFieldIntegrator::try_new(&g).unwrap();
        let exec = FieldExecutor::new(gfi, FDist::Identity, 4);
        let x = vec![1.0f32; 30];
        let out = exec.execute(&[x]).unwrap();
        assert_eq!(out[0].len(), 30);
        // Empty input is a shape error, not a panic.
        assert!(exec.execute(&[vec![]]).is_err());
    }

    fn stream_exec(
        n: usize,
        refresh_every: usize,
        slots: usize,
        seed: u64,
    ) -> StreamingFieldExecutor {
        let mut rng = Pcg::seed(seed);
        let tree = generators::random_tree(n, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        StreamingFieldExecutor::new(tfi, &f, 1, refresh_every, slots, 8).unwrap()
    }

    fn set_req(sid: usize, field: &[f32]) -> Vec<f32> {
        let mut r = vec![STREAM_OP_SET, sid as f32];
        r.extend_from_slice(field);
        r
    }

    fn update_req(sid: usize, rows: &[u32], vals: &[f32]) -> Vec<f32> {
        let mut r = vec![STREAM_OP_UPDATE, sid as f32, rows.len() as f32];
        r.extend(rows.iter().map(|&v| v as f32));
        r.extend_from_slice(vals);
        r
    }

    /// Two sessions with different fields: each session's responses
    /// must track its *own* field, including after interleaved updates
    /// — no cross-contamination through the shared tree / plans.
    #[test]
    fn streaming_sessions_do_not_cross_contaminate() {
        let n = 32;
        let exec = stream_exec(n, 4, 4, 11);
        let fa: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
        let fb: Vec<f32> = (0..n).map(|i| -(i as f32) * 0.2).collect();
        let outs = exec.execute(&[set_req(0, &fa), set_req(1, &fb)]).unwrap();
        assert_ne!(outs[0], outs[1]);
        // Interleave updates; session 1's output must stay what a fresh
        // session with the same field history produces.
        let u0 = exec.run_one(&update_req(0, &[3], &[9.0])).unwrap();
        let u1 = exec.run_one(&update_req(1, &[5], &[-7.0])).unwrap();
        assert_ne!(u0, u1);
        let fresh = stream_exec(n, 4, 4, 11); // same tree seed → same metric
        fresh.run_one(&set_req(0, &fb)).unwrap();
        let want = fresh.run_one(&update_req(0, &[5], &[-7.0])).unwrap();
        assert_eq!(u1, want, "session 1 must behave like an isolated session");
    }

    /// Malformed streaming requests fail alone: the session keeps its
    /// state, batch-mates keep their responses, and the worker (here:
    /// the executor) stays serviceable.
    #[test]
    fn streaming_malformed_update_fails_alone_without_poisoning_the_session() {
        let n = 24;
        let exec = stream_exec(n, 0, 2, 12);
        let field: Vec<f32> = (0..n).map(|i| (i as f32 * 0.3).sin()).collect();
        let base = exec.run_one(&set_req(0, &field)).unwrap();
        let bad_cases: Vec<Vec<f32>> = vec![
            vec![], // no header
            vec![3.0, 0.0, 1.0], // unknown opcode
            vec![STREAM_OP_UPDATE, 9.0, 0.0], // unknown session
            update_req(1, &[], &[]), // session never set
            update_req(0, &[24], &[1.0]), // row out of range
            update_req(0, &[0, 1], &[1.0]), // missing values
            vec![STREAM_OP_UPDATE, 0.0, 2.5, 1.0], // fractional row count
            vec![STREAM_OP_REPLAN, 0.0, 0.0, 1.0], // truncated replan (needs u, v, w)
            vec![STREAM_OP_REPLAN, 0.0, 99.0, 0.0, 1.0], // replan vertex out of range
            vec![STREAM_OP_REPLAN, 0.0, 0.0, 1.0, f32::NAN], // replan weight not finite
            vec![STREAM_OP_REPLAN, 1.0, 0.0, 1.0, 2.0], // replan on a never-set session
        ];
        let good = update_req(0, &[2], &[5.0]);
        let mut batch = bad_cases.clone();
        batch.push(good.clone());
        let results = exec.execute_each(&batch);
        for (i, r) in results[..bad_cases.len()].iter().enumerate() {
            assert!(r.is_err(), "malformed request {i} must fail");
        }
        let ok = results.last().unwrap().as_ref().expect("good batch-mate must succeed");
        // The good update saw the *original* session state: none of the
        // malformed requests may have mutated it.
        let fresh = stream_exec(n, 0, 2, 12);
        let fresh_base = fresh.run_one(&set_req(0, &field)).unwrap();
        assert_eq!(base, fresh_base);
        let want = fresh.run_one(&good).unwrap();
        assert_eq!(*ok, want, "failed requests must not have poisoned the session");
    }

    /// A replan request reweights the shared metric in place; the
    /// response must be **bit-identical** to a fresh executor built
    /// over the already-mutated tree (the in-place re-plan's rebuild
    /// equivalence, end to end through the wire protocol).
    #[test]
    fn streaming_replan_requests_reweight_the_shared_metric() {
        let n = 28;
        let mut rng = Pcg::seed(14);
        let tree = generators::random_tree(n, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
        let exec = StreamingFieldExecutor::new(tfi, &f, 1, 0, 2, 8).unwrap();
        let field: Vec<f32> = (0..n).map(|i| (i as f32 * 0.2).cos()).collect();
        let base = exec.run_one(&set_req(0, &field)).unwrap();
        let (eu, ev, ew) = tree.edges()[3];
        let w = (ew * 4.0) as f32;
        let got =
            exec.run_one(&[STREAM_OP_REPLAN, 0.0, eu as f32, ev as f32, w].to_vec()).unwrap();
        assert_ne!(got, base, "reweighting an edge must move the output");
        // Replaying the same weight is a no-op returning the same output.
        let again =
            exec.run_one(&[STREAM_OP_REPLAN, 0.0, eu as f32, ev as f32, w].to_vec()).unwrap();
        assert_eq!(got, again, "same-weight replan must be a no-op");
        // Oracle: a fresh executor over the mutated tree.
        let mut mt = tree.clone();
        assert!(mt.set_edge_weight(eu as usize, ev as usize, w as f64).is_some());
        let tfi2 = TreeFieldIntegrator::builder(&mt).threads(1).build().unwrap();
        let exec2 = StreamingFieldExecutor::new(tfi2, &f, 1, 0, 2, 8).unwrap();
        let want = exec2.run_one(&set_req(0, &field)).unwrap();
        assert_eq!(got, want, "post-replan output must match a rebuilt executor bit-for-bit");
    }

    /// End-to-end through the InferenceServer: streaming workers share
    /// one session table, shutdown drains every in-flight update, and
    /// the update-latency percentiles are populated.
    #[test]
    fn streaming_server_drains_updates_and_reports_update_latency() {
        let n = 16;
        let exec = Arc::new(stream_exec(n, 3, 2, 13));
        let metrics = Arc::clone(exec.metrics_registry());
        let factories: Vec<Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>> = (0..2)
            .map(|_| {
                let exec = Arc::clone(&exec);
                Box::new(move || {
                    Box::new(exec) as Box<dyn BatchExecutor>
                }) as Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>
            })
            .collect();
        let server = InferenceServer::start(
            factories,
            BatcherConfig {
                batch_size: 4,
                batch_timeout: Duration::from_millis(1),
                shed_after: None,
            },
            64,
        );
        let field = vec![1.0f32; n];
        server.submit_blocking(set_req(0, &field)).unwrap().wait().unwrap();
        let handles: Vec<_> = (0..20)
            .map(|i| {
                server
                    .submit_blocking(update_req(0, &[(i % n) as u32], &[i as f32]))
                    .unwrap()
            })
            .collect();
        server.shutdown(); // must drain every in-flight update
        let mut ok = 0;
        for h in handles {
            match h.wait() {
                Ok(out) => {
                    assert_eq!(out.len(), n);
                    ok += 1;
                }
                Err(e) => panic!("update lost during shutdown: {e}"),
            }
        }
        assert_eq!(ok, 20);
        let m = metrics.snapshot();
        assert_eq!(m.updates, 20, "every update must be recorded");
        assert!(m.update_p50 > 0.0 && m.update_p50 <= m.update_p95);
        assert!(m.update_p95 <= m.update_p99);
    }

    /// Satellite (deprecation shim): the legacy f32 wire and the typed
    /// wire must produce bit-identical outputs for ops 0/1/2 — the shim
    /// parses into the same enum and runs the same execution path.
    #[test]
    fn legacy_shim_matches_typed_wire_on_ops_0_1_2() {
        let n = 20;
        let mut rng = Pcg::seed(17);
        let tree = generators::random_tree(n, 0.2, 1.0, &mut rng);
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let build = || {
            let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build().unwrap();
            StreamingFieldExecutor::new(tfi, &f, 1, 2, 4, 8).unwrap()
        };
        let legacy = build();
        let typed = build(); // same tree → same metric
        let (eu, ev, _) = tree.edges()[2];
        let field: Vec<f32> = (0..n).map(|i| (i as f32 * 0.15).sin()).collect();
        let via_typed = |exec: &StreamingFieldExecutor, req: StreamRequest, id: u64| {
            let words = protocol::request_words(&req, id);
            let out = exec.run_one(&words).expect("typed request");
            let (got_id, resp) = protocol::response_from_words(&out).expect("typed response");
            assert_eq!(got_id, id, "response must echo the request id");
            match resp {
                StreamResponse::Output { values, .. } => values,
                other => panic!("expected Output, got {other:?}"),
            }
        };
        // op 0: set
        let l = legacy.run_one(&set_req(1, &field)).unwrap();
        let t = via_typed(
            &typed,
            StreamRequest::Set {
                session: 1,
                rows: n as u32,
                channels: 1,
                values: field.clone(),
            },
            100,
        );
        assert_eq!(l, t, "set: shim and typed wire must agree bit-for-bit");
        // op 1: update (legacy infers the width; typed states it)
        let l = legacy.run_one(&update_req(1, &[4, 9], &[2.5, -1.0])).unwrap();
        let t = via_typed(
            &typed,
            StreamRequest::Update {
                session: 1,
                rows: vec![4, 9],
                channels: 1,
                values: vec![2.5, -1.0],
            },
            101,
        );
        assert_eq!(l, t, "update: shim and typed wire must agree bit-for-bit");
        // op 2: replan (the legacy wire carries the weight as f32 —
        // feed the typed path the same f32-rounded weight)
        let l = legacy
            .run_one(&[STREAM_OP_REPLAN, 1.0, eu as f32, ev as f32, 1.5])
            .unwrap();
        let t = via_typed(
            &typed,
            StreamRequest::ReplanEdge {
                session: 1,
                u: eu,
                v: ev,
                w: 1.5f32 as f64,
            },
            102,
        );
        assert_eq!(l, t, "replan: shim and typed wire must agree bit-for-bit");
    }

    /// LRU admission: filling the table evicts the least-recently-used
    /// lease, the victim gets a typed `Rejected { Evicted }`, and a
    /// re-`Set` re-admits it with correct state.
    #[test]
    fn lru_eviction_rejects_typed_and_recovers_on_re_set() {
        let n = 16;
        let exec = stream_exec(n, 0, 2, 18); // capacity 2
        let field: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
        let set = |sid: u32| StreamRequest::Set {
            session: sid,
            rows: n as u32,
            channels: 1,
            values: field.clone(),
        };
        assert!(matches!(exec.execute_request(&set(10)), StreamResponse::Output { .. }));
        assert!(matches!(exec.execute_request(&set(11)), StreamResponse::Output { .. }));
        // Touch 10 so 11 is the LRU victim when 12 arrives.
        assert!(matches!(
            exec.execute_request(&StreamRequest::Lease { session: 10 }),
            StreamResponse::Output { .. }
        ));
        assert!(matches!(exec.execute_request(&set(12)), StreamResponse::Output { .. }));
        assert_eq!(exec.metrics().sessions_evicted, 1);
        match exec.execute_request(&StreamRequest::Update {
            session: 11,
            rows: vec![0],
            channels: 1,
            values: vec![1.0],
        }) {
            StreamResponse::Rejected { reason: RejectReason::Evicted, .. } => {}
            other => panic!("evicted session must be rejected typed, got {other:?}"),
        }
        // Survivors are untouched; the victim recovers via re-Set — and
        // behaves exactly like a session that was never evicted.
        assert!(matches!(
            exec.execute_request(&StreamRequest::Lease { session: 10 }),
            StreamResponse::Output { .. }
        ));
        // Re-Set evicts the current LRU (12) to make room — 11 is live
        // again with fresh state.
        assert!(matches!(exec.execute_request(&set(11)), StreamResponse::Output { .. }));
        let upd = StreamRequest::Update {
            session: 11,
            rows: vec![3],
            channels: 1,
            values: vec![7.0],
        };
        let got = match exec.execute_request(&upd) {
            StreamResponse::Output { values, .. } => values,
            other => panic!("re-admitted session must serve, got {other:?}"),
        };
        let oracle = stream_exec(n, 0, 2, 18);
        assert!(matches!(oracle.execute_request(&set(11)), StreamResponse::Output { .. }));
        let want = match oracle.execute_request(&upd) {
            StreamResponse::Output { values, .. } => values,
            other => panic!("oracle must serve, got {other:?}"),
        };
        assert_eq!(got, want, "re-admitted session must be bit-identical to a fresh one");
    }

    /// The per-session pending bound sheds with `SessionBusy` instead
    /// of queueing without limit, and the close/lease lifecycle is
    /// idempotent.
    #[test]
    fn session_busy_close_and_lease_lifecycle() {
        let n = 16;
        let exec = stream_exec(n, 0, 2, 19).with_max_pending(1);
        let field: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
        let set = StreamRequest::Set { session: 5, rows: n as u32, channels: 1, values: field };
        assert!(matches!(exec.execute_request(&set), StreamResponse::Output { .. }));
        // Saturate the pending counter by hand (as a stalled in-flight
        // update would) — the next update must shed typed.
        {
            let entry = exec.lookup(5).expect("leased");
            entry.pending.fetch_add(1, Ordering::Relaxed);
            match exec.execute_request(&StreamRequest::Update {
                session: 5,
                rows: vec![0],
                channels: 1,
                values: vec![1.0],
            }) {
                StreamResponse::Rejected { reason: RejectReason::SessionBusy, .. } => {}
                other => panic!("saturated session must shed, got {other:?}"),
            }
            entry.pending.fetch_sub(1, Ordering::Relaxed);
        }
        // Back under the bound: updates flow again.
        assert!(matches!(
            exec.execute_request(&StreamRequest::Update {
                session: 5,
                rows: vec![0],
                channels: 1,
                values: vec![1.0],
            }),
            StreamResponse::Output { .. }
        ));
        // Mismatched typed width fails alone.
        match exec.execute_request(&StreamRequest::Update {
            session: 5,
            rows: vec![0],
            channels: 3,
            values: vec![1.0, 2.0, 3.0],
        }) {
            StreamResponse::Error { message } => {
                assert!(message.contains("width"), "got: {message}")
            }
            other => panic!("width mismatch must error, got {other:?}"),
        }
        // Close is idempotent; a closed session is gone (not evicted).
        assert_eq!(
            exec.execute_request(&StreamRequest::Close { session: 5 }),
            StreamResponse::Closed { session: 5 }
        );
        assert_eq!(
            exec.execute_request(&StreamRequest::Close { session: 5 }),
            StreamResponse::Closed { session: 5 }
        );
        match exec.execute_request(&StreamRequest::Lease { session: 5 }) {
            StreamResponse::Error { message } => {
                assert!(message.contains("not initialised"), "got: {message}")
            }
            other => panic!("closed session must read as uninitialised, got {other:?}"),
        }
    }

    /// Ensemble serving path: the generic executor over an
    /// [`EnsembleFieldIntegrator`] shares the ensemble's pool, fans
    /// batches out, and isolates per-request failures.
    #[test]
    fn ensemble_executor_batch_fanout_and_error_isolation() {
        use crate::ftfi::ensemble::EnsembleFieldIntegrator;
        let mut rng = Pcg::seed(21);
        let g = generators::path_plus_random_edges(30, 15, &mut rng);
        let ens = EnsembleFieldIntegrator::builder(&g).trees(3).seed(5).build().unwrap();
        let shared = Arc::clone(ens.pool());
        let f = FDist::Exponential { lambda: -0.4, scale: 1.0 };
        let exec = FieldExecutor::new(ens, f, 4);
        assert!(
            Arc::ptr_eq(&exec.pool, &shared),
            "executor must reuse the ensemble's pool (one thread budget)"
        );
        let good = vec![1.0f32; 30];
        let bad = vec![1.0f32; 7];
        let results = exec.execute_each(&[good.clone(), bad, good]);
        assert_eq!(results.len(), 3);
        assert!(results[0].is_ok());
        match &results[1] {
            Err(e) => assert!(e.contains("shape mismatch"), "{e}"),
            Ok(_) => panic!("malformed request must fail alone"),
        }
        assert!(results[2].is_ok(), "batch-mates must not be poisoned");
        assert_eq!(results[0].as_ref().unwrap(), results[2].as_ref().unwrap());
    }

    /// Ensemble serving path: fixed `(seed, trees)` responses are
    /// bit-identical across thread counts (the CI thread matrix runs
    /// the whole suite under `FTFI_THREADS ∈ {1, 4}`; the explicit
    /// `.threads(..)` knobs pin both engines regardless).
    #[test]
    fn ensemble_executor_is_seed_deterministic_across_thread_counts() {
        use crate::ftfi::ensemble::EnsembleFieldIntegrator;
        let mut rng = Pcg::seed(22);
        // n ≥ 256 so both the batch fan-out and the tree axis engage.
        let g = generators::path_plus_random_edges(300, 150, &mut rng);
        let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
        let build = |threads: usize| {
            let b = EnsembleFieldIntegrator::builder(&g).trees(3).seed(9).threads(threads);
            b.build().unwrap()
        };
        let exec_s = FieldExecutor::new(build(1), f.clone(), 8);
        let exec_p = FieldExecutor::new(build(4), f, 8);
        let inputs: Vec<Vec<f32>> = (0..4)
            .map(|k| (0..300).map(|i| ((i + 97 * k) as f32 * 0.01).sin()).collect())
            .collect();
        let a = exec_s.execute_each(&inputs);
        let b = exec_p.execute_each(&inputs);
        assert_eq!(a.len(), b.len());
        for (i, (ra, rb)) in a.iter().zip(&b).enumerate() {
            let (ra, rb) = (ra.as_ref().unwrap(), rb.as_ref().unwrap());
            assert_eq!(ra, rb, "request {i}: ensemble response must be bit-identical");
        }
    }
}
