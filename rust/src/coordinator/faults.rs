//! Deterministic fault injection for the serving stack: a seeded
//! [`FaultPlan`] drives frame corruption, response drops/duplication,
//! client disconnects, injected latency and worker panics — the chaos
//! harness behind `tests/serving_faults.rs` and the `loadgen` soak.
//!
//! Zero cost when off: [`Faults::new`] returns `None` for an all-zero
//! plan, so the serving paths carry an `Option<Arc<Faults>>` that is
//! `None` in production and never rolls a die.
//!
//! Injection points (and who applies them):
//!
//! | fault              | site                          | detected by            |
//! |--------------------|-------------------------------|------------------------|
//! | corrupt_frame      | [`FaultyExecutor`] / TCP front| frame checksum         |
//! | drop_response      | TCP response writer           | client req-id ledger   |
//! | duplicate_response | TCP response writer           | client req-id ledger   |
//! | disconnect         | loadgen client (mid-stream)   | reconnect + re-lease   |
//! | latency            | [`FaultyExecutor`]            | latency percentiles    |
//! | panic_worker       | [`FaultyExecutor`]            | batcher `catch_unwind` |
//!
//! Every probability draw flows through one seeded [`Pcg`] behind a
//! mutex, so a `(plan, seed)` pair replays the same fault schedule for
//! a serialized request sequence — the REPRO contract of the chaos
//! test.

use super::batcher::BatchExecutor;
use super::protocol;
use crate::ml::rng::Pcg;
use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::Mutex;
use std::time::Duration;

/// Seeded fault schedule. All probabilities are per-event in `[0, 1]`;
/// an all-zero plan is "off" and costs nothing at runtime.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// RNG seed: the same `(plan, seed)` replays the same schedule.
    pub seed: u64,
    /// Probability a request frame is corrupted (one byte flipped)
    /// before it reaches the decoder.
    pub corrupt_frame: f64,
    /// Probability the TCP writer silently drops a response frame.
    pub drop_response: f64,
    /// Probability the TCP writer sends a response frame twice.
    pub duplicate_response: f64,
    /// Probability a loadgen client disconnects mid-stream.
    pub disconnect: f64,
    /// Probability a request's execution is delayed by `latency_ms`.
    pub latency: f64,
    /// Injected delay magnitude (only read when `latency` fires).
    pub latency_ms: u64,
    /// Probability the worker panics *before* touching session state
    /// (the batcher's `catch_unwind` must fan it out as per-request
    /// errors without losing a response or poisoning a session).
    pub panic_worker: f64,
}

impl FaultPlan {
    /// All faults disabled.
    pub fn off() -> Self {
        FaultPlan::default()
    }

    /// Is every fault probability zero?
    pub fn is_off(&self) -> bool {
        self.corrupt_frame == 0.0
            && self.drop_response == 0.0
            && self.duplicate_response == 0.0
            && self.disconnect == 0.0
            && self.latency == 0.0
            && self.panic_worker == 0.0
    }

    /// A moderate mixed schedule for soaks: every fault class enabled
    /// at rates low enough that most traffic still succeeds.
    pub fn chaos(seed: u64) -> Self {
        FaultPlan {
            seed,
            corrupt_frame: 0.02,
            drop_response: 0.01,
            duplicate_response: 0.01,
            disconnect: 0.002,
            latency: 0.02,
            latency_ms: 2,
            panic_worker: 0.005,
        }
    }
}

/// Point-in-time injection counters (what the plan actually did).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    pub frames_corrupted: u64,
    pub responses_dropped: u64,
    pub responses_duplicated: u64,
    pub disconnects: u64,
    pub delays_injected: u64,
    pub panics_injected: u64,
}

/// Runtime fault injector: the seeded die plus injection counters.
pub struct Faults {
    plan: FaultPlan,
    rng: Mutex<Pcg>,
    frames_corrupted: AtomicU64,
    responses_dropped: AtomicU64,
    responses_duplicated: AtomicU64,
    disconnects: AtomicU64,
    delays_injected: AtomicU64,
    panics_injected: AtomicU64,
}

impl Faults {
    /// Build the injector — `None` when the plan is off, so disabled
    /// fault config is zero-cost on every serving path.
    pub fn new(plan: &FaultPlan) -> Option<std::sync::Arc<Faults>> {
        if plan.is_off() {
            return None;
        }
        Some(std::sync::Arc::new(Faults {
            plan: plan.clone(),
            rng: Mutex::new(Pcg::new(plan.seed, 0xFA17)),
            frames_corrupted: AtomicU64::new(0),
            responses_dropped: AtomicU64::new(0),
            responses_duplicated: AtomicU64::new(0),
            disconnects: AtomicU64::new(0),
            delays_injected: AtomicU64::new(0),
            panics_injected: AtomicU64::new(0),
        }))
    }

    /// The schedule this injector runs.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// One Bernoulli draw plus a u32 payload for site selection, from
    /// the shared seeded stream. Poison recovery: the RNG state is
    /// always valid, so a panicked sibling must not silence faults.
    fn roll(&self, p: f64) -> Option<u32> {
        if p <= 0.0 {
            return None;
        }
        let mut rng = self.rng.lock().unwrap_or_else(|e| e.into_inner());
        if rng.uniform() < p {
            Some(rng.next_u32())
        } else {
            None
        }
    }

    /// Maybe flip one byte of a frame payload (checksum territory —
    /// never the first byte, so the frame still parses far enough to
    /// reach the checksum). Returns whether corruption was applied.
    pub fn corrupt_payload(&self, payload: &mut [u8]) -> bool {
        if payload.len() < 2 {
            return false;
        }
        match self.roll(self.plan.corrupt_frame) {
            Some(die) => {
                let at = 1 + (die as usize) % (payload.len() - 1);
                payload[at] ^= 1u8 << (die % 8);
                self.frames_corrupted.fetch_add(1, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// Pre-execution hook: inject latency, then maybe panic the worker.
    /// The panic fires *before* any session state is touched, so the
    /// exactly-one-response and session-integrity invariants survive it.
    pub fn before_execute(&self) {
        if self.roll(self.plan.latency).is_some() {
            self.delays_injected.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(self.plan.latency_ms));
        }
        if self.roll(self.plan.panic_worker).is_some() {
            self.panics_injected.fetch_add(1, Ordering::Relaxed);
            // lint: allow(unchecked-panic) — the whole point of this
            // injector: a deliberate worker panic the batcher's
            // catch_unwind must convert into per-request errors.
            panic!("fault-injected worker panic");
        }
    }

    /// Should the TCP writer drop the next response frame?
    pub fn take_drop_response(&self) -> bool {
        let hit = self.roll(self.plan.drop_response).is_some();
        if hit {
            self.responses_dropped.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Should the TCP writer send the next response frame twice?
    pub fn take_duplicate_response(&self) -> bool {
        let hit = self.roll(self.plan.duplicate_response).is_some();
        if hit {
            self.responses_duplicated.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Should a loadgen client tear its connection down now?
    pub fn take_disconnect(&self) -> bool {
        let hit = self.roll(self.plan.disconnect).is_some();
        if hit {
            self.disconnects.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Injection counters so far.
    pub fn counters(&self) -> FaultCounters {
        FaultCounters {
            frames_corrupted: self.frames_corrupted.load(Ordering::Relaxed),
            responses_dropped: self.responses_dropped.load(Ordering::Relaxed),
            responses_duplicated: self.responses_duplicated.load(Ordering::Relaxed),
            disconnects: self.disconnects.load(Ordering::Relaxed),
            delays_injected: self.delays_injected.load(Ordering::Relaxed),
            panics_injected: self.panics_injected.load(Ordering::Relaxed),
        }
    }
}

/// A [`BatchExecutor`] wrapper that injects request-path faults
/// (latency, worker panics, frame corruption) in front of `inner`.
/// Corruption targets typed-wire word payloads (flipping a byte the
/// checksum must catch) and falls back to NaN-poisoning a legacy value
/// — either way the request must fail alone, typed.
pub struct FaultyExecutor<E: BatchExecutor> {
    inner: E,
    faults: std::sync::Arc<Faults>,
}

impl<E: BatchExecutor> FaultyExecutor<E> {
    pub fn new(inner: E, faults: std::sync::Arc<Faults>) -> Self {
        FaultyExecutor { inner, faults }
    }

    fn maul(&self, input: &[f32]) -> Vec<f32> {
        let mut words = input.to_vec();
        if protocol::is_typed_words(&words) && words.len() > 2 {
            if let Some(die) = self.faults.roll(self.faults.plan.corrupt_frame) {
                // Flip a byte inside the payload words (past magic +
                // length, so the frame still reaches the checksum).
                let at = 2 + (die as usize) % (words.len() - 2);
                let bits = words[at].to_bits() ^ (1u32 << (die % 32));
                words[at] = f32::from_bits(bits);
                self.faults.frames_corrupted.fetch_add(1, Ordering::Relaxed);
            }
        } else if !words.is_empty() && self.faults.roll(self.faults.plan.corrupt_frame).is_some() {
            let last = words.len() - 1;
            words[last] = f32::NAN;
            self.faults.frames_corrupted.fetch_add(1, Ordering::Relaxed);
        }
        words
    }
}

impl<E: BatchExecutor> BatchExecutor for FaultyExecutor<E> {
    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        self.execute_each(inputs).into_iter().collect()
    }

    fn execute_each(&self, inputs: &[Vec<f32>]) -> Vec<Result<Vec<f32>, String>> {
        self.faults.before_execute();
        let mauled: Vec<Vec<f32>> = inputs.iter().map(|i| self.maul(i)).collect();
        self.inner.execute_each(&mauled)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_plan_builds_no_injector() {
        assert!(FaultPlan::off().is_off());
        assert!(Faults::new(&FaultPlan::off()).is_none());
        assert!(!FaultPlan::chaos(1).is_off());
        assert!(Faults::new(&FaultPlan::chaos(1)).is_some());
    }

    #[test]
    fn same_seed_replays_the_same_schedule() {
        let plan = FaultPlan { seed: 9, corrupt_frame: 0.5, ..FaultPlan::default() };
        let run = || {
            let f = Faults::new(&plan).expect("plan is on");
            (0..64).map(|_| f.roll(plan.corrupt_frame).is_some()).collect::<Vec<_>>()
        };
        assert_eq!(run(), run(), "seeded schedules must replay bit-identically");
        let other = Faults::new(&FaultPlan { seed: 10, ..plan.clone() }).expect("on");
        let b: Vec<bool> = (0..64).map(|_| other.roll(plan.corrupt_frame).is_some()).collect();
        assert_ne!(run(), b, "a different seed must give a different schedule");
    }

    #[test]
    fn corruption_always_breaks_the_checksum() {
        let plan = FaultPlan { seed: 3, corrupt_frame: 1.0, ..FaultPlan::default() };
        let faults = Faults::new(&plan).expect("on");
        for id in 0..32u64 {
            let req = protocol::StreamRequest::Update {
                session: 1,
                rows: vec![0, 2],
                channels: 1,
                values: vec![0.5, -0.5],
            };
            let mut payload = protocol::encode_request(&req, id);
            assert!(faults.corrupt_payload(&mut payload));
            assert!(
                protocol::decode_request(&payload).is_err(),
                "flipped byte must never decode cleanly (id {id})"
            );
        }
        assert_eq!(faults.counters().frames_corrupted, 32);
    }

    #[test]
    fn faulty_executor_panic_is_injected_before_delegation() {
        struct Inner;
        impl BatchExecutor for Inner {
            fn max_batch(&self) -> usize {
                1
            }
            fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
                Ok(inputs.to_vec())
            }
        }
        let plan = FaultPlan { seed: 1, panic_worker: 1.0, ..FaultPlan::default() };
        let faults = Faults::new(&plan).expect("on");
        let exec = FaultyExecutor::new(Inner, std::sync::Arc::clone(&faults));
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            exec.execute_each(&[vec![1.0]])
        }));
        assert!(caught.is_err(), "panic_worker = 1.0 must panic");
        assert_eq!(faults.counters().panics_injected, 1);
    }

    #[test]
    fn counters_track_each_fault_class() {
        let plan = FaultPlan {
            seed: 5,
            drop_response: 1.0,
            duplicate_response: 1.0,
            disconnect: 1.0,
            latency: 1.0,
            latency_ms: 0,
            ..FaultPlan::default()
        };
        let f = Faults::new(&plan).expect("on");
        assert!(f.take_drop_response());
        assert!(f.take_duplicate_response());
        assert!(f.take_disconnect());
        f.before_execute(); // latency only (panic_worker = 0)
        let c = f.counters();
        assert_eq!(c.responses_dropped, 1);
        assert_eq!(c.responses_duplicated, 1);
        assert_eq!(c.disconnects, 1);
        assert_eq!(c.delays_injected, 1);
        assert_eq!(c.panics_injected, 0);
    }
}
