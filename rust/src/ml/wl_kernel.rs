//! Weisfeiler–Lehman subtree features — the WL-VH baseline of Table 4.
//!
//! The paper positions FTFI among classical graph kernels; WL-VH (vertex
//! histogram over WL colour refinements) is the strongest cheap baseline
//! in its Table 4. This implementation hashes iterated neighbourhood
//! colour multisets for `h` rounds and featurises each graph by its
//! (dimension-reduced) colour histogram, ready for the same random-forest
//! pipeline as the spectral features.

use crate::graph::Graph;

/// Number of hash buckets the colour histogram is folded into (keeps the
/// feature dimension fixed and comparable across datasets).
pub const WL_BUCKETS: usize = 64;

fn mix(h: u64) -> u64 {
    // splitmix64 finaliser — good avalanche for colour hashing.
    let mut z = h.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// WL colour refinement for `rounds` iterations; initial colours are
/// vertex degrees (the standard unlabelled-graph convention).
pub fn wl_colors(g: &Graph, rounds: usize) -> Vec<Vec<u64>> {
    let n = g.n();
    let mut colors: Vec<u64> = (0..n).map(|v| mix(g.degree(v) as u64)).collect();
    let mut history = vec![colors.clone()];
    let mut neigh = Vec::new();
    for _ in 0..rounds {
        let mut next = vec![0u64; n];
        for (v, slot) in next.iter_mut().enumerate() {
            neigh.clear();
            neigh.extend(g.neighbors(v).map(|(u, _)| colors[u as usize]));
            neigh.sort_unstable();
            let mut h = mix(colors[v]);
            for &c in &neigh {
                h = mix(h ^ c.rotate_left(17));
            }
            *slot = h;
        }
        colors = next;
        history.push(colors.clone());
    }
    history
}

/// WL-VH feature vector: bucket-folded colour histograms of all rounds,
/// L1-normalised per round.
pub fn wl_features(g: &Graph, rounds: usize) -> Vec<f64> {
    let history = wl_colors(g, rounds);
    let mut out = Vec::with_capacity((rounds + 1) * WL_BUCKETS);
    let inv_n = 1.0 / g.n().max(1) as f64;
    for colors in history {
        let mut hist = vec![0.0f64; WL_BUCKETS];
        for c in colors {
            hist[(c % WL_BUCKETS as u64) as usize] += inv_n;
        }
        out.extend(hist);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::ml::dataset::{fold_split, stratified_kfold};
    use crate::ml::metrics::accuracy;
    use crate::ml::random_forest::{ForestParams, RandomForest};
    use crate::ml::rng::Pcg;

    #[test]
    fn isomorphic_graphs_same_features() {
        // Same structure, different vertex order (relabelled path).
        let a = Graph::from_edges(4, &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0)]);
        let b = Graph::from_edges(4, &[(3, 2, 1.0), (2, 0, 1.0), (0, 1, 1.0)]);
        assert_eq!(wl_features(&a, 3), wl_features(&b, 3));
    }

    #[test]
    fn wl_distinguishes_path_from_star() {
        let path = Graph::from_edges(5, &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0), (3, 4, 1.0)]);
        let star = Graph::from_edges(5, &[(0, 1, 1.0), (0, 2, 1.0), (0, 3, 1.0), (0, 4, 1.0)]);
        assert_ne!(wl_features(&path, 2), wl_features(&star, 2));
    }

    #[test]
    fn refinement_stabilises_on_regular_graphs() {
        // A cycle is degree-regular: all vertices share one colour forever.
        let cyc = Graph::from_edges(
            5,
            &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0), (3, 4, 1.0), (4, 0, 1.0)],
        );
        for colors in wl_colors(&cyc, 3) {
            let first = colors[0];
            assert!(colors.iter().all(|&c| c == first));
        }
    }

    #[test]
    fn wl_classifies_tu_style_dataset() {
        // End-to-end: WL-VH features + random forest beat chance on the
        // synthetic TU-style classes (the Table 4 baseline pipeline).
        let spec = crate::graph::tu_dataset::TuSpec {
            name: "WLTEST",
            n_graphs: 60,
            avg_nodes: 28,
            n_classes: 2,
        };
        let ds = crate::graph::tu_dataset::generate(&spec, 2);
        let feats: Vec<Vec<f64>> = ds.graphs.iter().map(|g| wl_features(g, 3)).collect();
        let mut rng = Pcg::seed(5);
        let folds = stratified_kfold(&ds.labels, 4, &mut rng);
        let mut accs = Vec::new();
        for f in 0..4 {
            let (tr, te) = fold_split(&folds, f);
            let xtr: Vec<Vec<f64>> = tr.iter().map(|&i| feats[i].clone()).collect();
            let ytr: Vec<usize> = tr.iter().map(|&i| ds.labels[i]).collect();
            let rf = RandomForest::fit(&xtr, &ytr, &ForestParams::default(), &mut rng);
            let pred: Vec<usize> = te.iter().map(|&i| rf.predict(&feats[i])).collect();
            let truth: Vec<usize> = te.iter().map(|&i| ds.labels[i]).collect();
            accs.push(accuracy(&pred, &truth));
        }
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        assert!(mean > 0.7, "WL accuracy {mean}");
        let _ = generators::grid_2d(2, 2, 1.0); // keep import used
    }
}
