//! Train/test splitting and k-fold cross-validation — the evaluation
//! protocol of §4.2 / Appendix D.4 (Errica et al. 2020: stratified
//! 10-fold CV, repeated over seeds).

use crate::ml::rng::Pcg;

/// Index-level k-fold split, stratified by label so every fold keeps the
/// class balance.
pub fn stratified_kfold(labels: &[usize], k: usize, rng: &mut Pcg) -> Vec<Vec<usize>> {
    assert!(k >= 2);
    let n_classes = labels.iter().copied().max().map_or(0, |m| m + 1);
    let mut folds: Vec<Vec<usize>> = vec![Vec::new(); k];
    for c in 0..n_classes {
        let mut idx: Vec<usize> =
            (0..labels.len()).filter(|&i| labels[i] == c).collect();
        rng.shuffle(&mut idx);
        for (j, i) in idx.into_iter().enumerate() {
            folds[j % k].push(i);
        }
    }
    folds
}

/// Train/test indices for fold `f` out of `folds`.
pub fn fold_split(folds: &[Vec<usize>], f: usize) -> (Vec<usize>, Vec<usize>) {
    let test = folds[f].clone();
    let train: Vec<usize> =
        folds.iter().enumerate().filter(|&(i, _)| i != f).flat_map(|(_, v)| v.iter().copied()).collect();
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn folds_partition_everything() {
        let labels: Vec<usize> = (0..100).map(|i| i % 3).collect();
        let mut rng = Pcg::seed(1);
        let folds = stratified_kfold(&labels, 5, &mut rng);
        let total: usize = folds.iter().map(|f| f.len()).sum();
        assert_eq!(total, 100);
        let mut all: Vec<usize> = folds.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn folds_are_stratified() {
        let labels: Vec<usize> = (0..90).map(|i| i % 3).collect();
        let mut rng = Pcg::seed(2);
        let folds = stratified_kfold(&labels, 5, &mut rng);
        for f in &folds {
            for c in 0..3 {
                let count = f.iter().filter(|&&i| labels[i] == c).count();
                assert!(count == 6, "class {c} count {count}");
            }
        }
    }

    #[test]
    fn split_disjoint() {
        let labels: Vec<usize> = (0..40).map(|i| i % 2).collect();
        let mut rng = Pcg::seed(3);
        let folds = stratified_kfold(&labels, 4, &mut rng);
        let (train, test) = fold_split(&folds, 2);
        assert_eq!(train.len() + test.len(), 40);
        for t in &test {
            assert!(!train.contains(t));
        }
    }
}
