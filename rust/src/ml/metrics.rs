//! Evaluation metrics for the application experiments.

/// Classification accuracy.
pub fn accuracy(pred: &[usize], truth: &[usize]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter().zip(truth).filter(|(p, t)| p == t).count() as f64 / pred.len() as f64
}

/// Mean and (population) standard deviation.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

/// Mean cosine similarity between row pairs of two `n×3` normal fields,
/// ignoring rows where either side is (near) zero — the Fig. 4 metric.
pub fn mean_cosine_rows(pred: &crate::linalg::matrix::Matrix, truth: &crate::linalg::matrix::Matrix) -> f64 {
    assert_eq!(pred.rows(), truth.rows());
    let mut total = 0.0;
    let mut count = 0usize;
    for i in 0..pred.rows() {
        let c = crate::linalg::matrix::cosine_similarity(pred.row(i), truth.row(i));
        if c != 0.0 {
            total += c;
            count += 1;
        }
    }
    if count == 0 {
        0.0
    } else {
        total / count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;

    #[test]
    fn accuracy_basics() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 4]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn mean_std_known() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0, 4.0]);
        assert!((m - 2.5).abs() < 1e-12);
        assert!((s - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn cosine_rows() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 0.0, 1.0, 0.0]);
        // Row 0: cos=1 (counted); row 1: cos=0 (skipped as degenerate).
        assert!((mean_cosine_rows(&a, &b) - 1.0).abs() < 1e-12);
    }
}
