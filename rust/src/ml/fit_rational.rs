//! Learnable `f`-distance matrices (§4.3, Eq. 6–7): fit the coefficients
//! of a rational `f` so the tree metric `f(dist_T)` matches the graph
//! metric `dist_G`, by MSE gradient descent (Adam) on sampled vertex
//! pairs — the light-weight loss that §4.3 shows already shrinks the
//! relative Frobenius error within ~100 steps.

use crate::ftfi::functions::{horner, FDist};
use crate::graph::shortest_path::dijkstra;
use crate::graph::Graph;
use crate::ml::rng::Pcg;
use crate::tree::Tree;

/// A trainable rational function `f(x) = P(x)/Q(x)` with `Q(0)=b₀` fixed
/// to 1 (removes the scale ambiguity of Eq. 7).
#[derive(Clone, Debug)]
pub struct RationalModel {
    /// Numerator coefficients a₀..a_t (low→high).
    pub num: Vec<f64>,
    /// Denominator coefficients b₁..b_s (b₀ ≡ 1).
    pub den_tail: Vec<f64>,
}

impl RationalModel {
    /// Identity-like initialisation for the given degrees:
    /// `P(x) = x`, `Q(x) = 1` padded to the requested lengths.
    pub fn new(num_degree: usize, den_degree: usize) -> Self {
        let mut num = vec![0.0; num_degree + 1];
        if num_degree >= 1 {
            num[1] = 1.0;
        } else {
            num[0] = 1.0;
        }
        RationalModel { num, den_tail: vec![0.0; den_degree] }
    }

    fn den_full(&self) -> Vec<f64> {
        let mut q = Vec::with_capacity(self.den_tail.len() + 1);
        q.push(1.0);
        q.extend_from_slice(&self.den_tail);
        q
    }

    /// Evaluate the model.
    pub fn eval(&self, x: f64) -> f64 {
        horner(&self.num, x) / horner(&self.den_full(), x)
    }

    /// Export as an [`FDist`] usable by the integrators.
    pub fn to_fdist(&self) -> FDist {
        FDist::Rational { num: self.num.clone(), den: self.den_full() }
    }

    /// Parameter count (the paper's "3 extra learnable parameters" refers
    /// to a degree-1 numerator + degree-1 denominator configuration).
    pub fn n_params(&self) -> usize {
        self.num.len() + self.den_tail.len()
    }
}

/// One training tuple of Eq. 6: `(d_G(v,w), d_T(v,w))`.
#[derive(Clone, Copy, Debug)]
pub struct PairSample {
    pub d_graph: f64,
    pub d_tree: f64,
}

/// Sample `n_pairs` random vertex pairs with graph and tree distances
/// (each sample costs one Dijkstra, i.e. `O(N log N)` as the paper notes).
pub fn sample_pairs(g: &Graph, tree: &Tree, n_pairs: usize, rng: &mut Pcg) -> Vec<PairSample> {
    let n = g.n();
    assert!(n >= 2);
    let mut out = Vec::with_capacity(n_pairs);
    // Batch by source to amortise Dijkstra over several targets.
    let per_source = 8.min(n_pairs.max(1));
    while out.len() < n_pairs {
        let v = rng.below(n);
        let dg = dijkstra(g, v);
        let dt = tree.distances_from(v);
        for _ in 0..per_source {
            if out.len() >= n_pairs {
                break;
            }
            let w = rng.below(n);
            if w == v {
                continue;
            }
            out.push(PairSample { d_graph: dg[w], d_tree: dt[w] });
        }
    }
    out
}

/// Adam optimiser state.
struct Adam {
    m: Vec<f64>,
    v: Vec<f64>,
    t: usize,
    lr: f64,
}

impl Adam {
    fn new(dim: usize, lr: f64) -> Self {
        Adam { m: vec![0.0; dim], v: vec![0.0; dim], t: 0, lr }
    }

    fn step(&mut self, params: &mut [f64], grads: &[f64]) {
        const B1: f64 = 0.9;
        const B2: f64 = 0.999;
        const EPS: f64 = 1e-8;
        self.t += 1;
        let bc1 = 1.0 - B1.powi(self.t as i32);
        let bc2 = 1.0 - B2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = B1 * self.m[i] + (1.0 - B1) * grads[i];
            self.v[i] = B2 * self.v[i] + (1.0 - B2) * grads[i] * grads[i];
            params[i] -= self.lr * (self.m[i] / bc1) / ((self.v[i] / bc2).sqrt() + EPS);
        }
    }
}

/// Training record per iteration.
#[derive(Debug, Clone)]
pub struct FitTrace {
    pub loss: Vec<f64>,
}

/// Fit the rational model on the pair samples by full-batch Adam.
/// Returns the per-iteration MSE trace (the Fig. 6/8/9 curves).
pub fn fit(
    model: &mut RationalModel,
    data: &[PairSample],
    iters: usize,
    lr: f64,
) -> FitTrace {
    let np = model.num.len();
    let nd = model.den_tail.len();
    let mut adam = Adam::new(np + nd, lr);
    let mut loss_trace = Vec::with_capacity(iters);
    for _ in 0..iters {
        let mut grads = vec![0.0; np + nd];
        let mut loss = 0.0;
        for s in data {
            let x = s.d_tree;
            let p = horner(&model.num, x);
            let q = horner(&model.den_full(), x);
            // Guard against denominator collapse during training.
            let q = if q.abs() < 1e-6 { 1e-6f64.copysign(q) } else { q };
            let f = p / q;
            let err = f - s.d_graph;
            loss += err * err;
            // d f/d a_k = x^k / q ; d f/d b_k = -p·x^k/q² (k ≥ 1).
            let mut xk = 1.0;
            for k in 0..np {
                grads[k] += 2.0 * err * xk / q;
                xk *= x;
            }
            let mut xk = x;
            for k in 0..nd {
                grads[np + k] += 2.0 * err * (-p * xk / (q * q));
                xk *= x;
            }
        }
        let scale = 1.0 / data.len().max(1) as f64;
        loss *= scale;
        grads.iter_mut().for_each(|g| *g *= scale);
        // Clip the gradient norm: rational gradients explode whenever the
        // denominator wanders near a root of Q during training.
        let gnorm = grads.iter().map(|g| g * g).sum::<f64>().sqrt();
        if gnorm > 10.0 {
            let c = 10.0 / gnorm;
            grads.iter_mut().for_each(|g| *g *= c);
        }
        let mut params: Vec<f64> =
            model.num.iter().chain(model.den_tail.iter()).copied().collect();
        adam.step(&mut params, &grads);
        model.num.copy_from_slice(&params[..np]);
        model.den_tail.copy_from_slice(&params[np..]);
        loss_trace.push(loss);
    }
    FitTrace { loss: loss_trace }
}

/// The §4.3 evaluation metric: relative Frobenius error
/// `‖M_f^T − M_id^G‖_F / ‖M_id^G‖_F` (O(N²); evaluation only — training
/// never touches it).
pub fn relative_frobenius_error(g: &Graph, tree: &Tree, f: &FDist) -> f64 {
    let n = g.n();
    let mut num = 0.0;
    let mut den = 0.0;
    for v in 0..n {
        let dg = dijkstra(g, v);
        let dt = tree.distances_from(v);
        for w in 0..n {
            let fd = f.eval(dt[w]);
            num += (fd - dg[w]) * (fd - dg[w]);
            den += dg[w] * dg[w];
        }
    }
    (num / den).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::graph::mst::minimum_spanning_tree;

    #[test]
    fn model_eval_and_export_agree() {
        let m = RationalModel { num: vec![0.5, 2.0], den_tail: vec![0.25] };
        let f = m.to_fdist();
        for &x in &[0.0, 0.7, 3.0] {
            assert!((m.eval(x) - f.eval(x)).abs() < 1e-12);
        }
    }

    #[test]
    fn recovers_identity_when_tree_equals_graph() {
        // When the graph is its own MST, f(x)=x is optimal; training from
        // a perturbed start should drive the loss near zero.
        let mut rng = Pcg::seed(1);
        let tree = generators::random_tree(60, 0.2, 1.0, &mut rng);
        let g = tree.to_graph();
        let mst = minimum_spanning_tree(&g);
        let data = sample_pairs(&g, &mst, 120, &mut rng);
        let mut model = RationalModel::new(2, 2);
        model.num[1] = 0.3; // perturbed start
        let trace = fit(&mut model, &data, 400, 0.05);
        let final_loss = *trace.loss.last().unwrap();
        assert!(final_loss < 0.05, "loss={final_loss}");
    }

    #[test]
    fn training_reduces_frobenius_error() {
        // The paper's core §4.3 claim: MSE training on ~100 pairs reduces
        // the (expensive, never-trained-on) relative Frobenius error.
        let mut rng = Pcg::seed(2);
        let g = generators::path_plus_random_edges(120, 90, &mut rng);
        let tree = minimum_spanning_tree(&g);
        let data = sample_pairs(&g, &tree, 100, &mut rng);
        let mut model = RationalModel::new(2, 2);
        let before = relative_frobenius_error(&g, &tree, &model.to_fdist());
        fit(&mut model, &data, 300, 0.03);
        let after = relative_frobenius_error(&g, &tree, &model.to_fdist());
        assert!(after < before, "before={before} after={after}");
    }

    #[test]
    fn loss_trace_monotone_ish() {
        let mut rng = Pcg::seed(3);
        let g = generators::path_plus_random_edges(80, 50, &mut rng);
        let tree = minimum_spanning_tree(&g);
        let data = sample_pairs(&g, &tree, 80, &mut rng);
        let mut model = RationalModel::new(1, 1);
        let trace = fit(&mut model, &data, 200, 0.02);
        // End loss well below start loss (not strictly monotone — Adam).
        assert!(trace.loss.last().unwrap() < &(trace.loss[0] * 0.9));
    }

    #[test]
    fn pair_samples_are_consistent_metrics() {
        let mut rng = Pcg::seed(4);
        let g = generators::path_plus_random_edges(50, 25, &mut rng);
        let tree = minimum_spanning_tree(&g);
        let data = sample_pairs(&g, &tree, 60, &mut rng);
        for s in &data {
            // Tree distance dominates graph distance (tree is a subgraph).
            assert!(s.d_tree + 1e-9 >= s.d_graph);
            assert!(s.d_graph > 0.0);
        }
    }
}
