//! Random forest classifier over dense feature vectors, built from
//! scratch (no external ML crates offline): CART decision trees with Gini
//! impurity, feature sub-sampling (√d per split) and bootstrap bagging —
//! the classifier of the §4.2 graph-classification pipeline (de Lara &
//! Pineau 2018 use exactly this setup over spectral features).

use crate::ml::rng::Pcg;

/// One node of a decision tree (arena layout).
#[derive(Debug, Clone)]
enum Node {
    Leaf { class: usize },
    Split { feature: usize, threshold: f64, left: usize, right: usize },
}

/// A single CART tree.
#[derive(Debug)]
pub struct DecisionTree {
    nodes: Vec<Node>,
    n_classes: usize,
}

/// Training hyper-parameters.
#[derive(Clone, Debug)]
pub struct ForestParams {
    pub n_trees: usize,
    pub max_depth: usize,
    pub min_samples_split: usize,
    /// Features tried per split; 0 = √d.
    pub max_features: usize,
}

impl Default for ForestParams {
    fn default() -> Self {
        ForestParams { n_trees: 50, max_depth: 12, min_samples_split: 4, max_features: 0 }
    }
}

fn majority(labels: &[usize], idx: &[usize], n_classes: usize) -> usize {
    let mut counts = vec![0usize; n_classes];
    for &i in idx {
        counts[labels[i]] += 1;
    }
    counts
        .iter()
        .enumerate()
        .max_by_key(|&(_, c)| *c)
        .map(|(k, _)| k)
        .unwrap_or(0)
}

fn gini(counts: &[usize], total: usize) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    1.0 - counts.iter().map(|&c| (c as f64 / t).powi(2)).sum::<f64>()
}

impl DecisionTree {
    /// Fit on the rows of `x` (`n×d`, row-major slice accessor) selected
    /// by `idx`.
    fn fit(
        x: &[Vec<f64>],
        labels: &[usize],
        idx: Vec<usize>,
        n_classes: usize,
        params: &ForestParams,
        rng: &mut Pcg,
    ) -> Self {
        let mut tree = DecisionTree { nodes: Vec::new(), n_classes };
        tree.grow(x, labels, idx, params, 0, rng);
        tree
    }

    fn grow(
        &mut self,
        x: &[Vec<f64>],
        labels: &[usize],
        idx: Vec<usize>,
        params: &ForestParams,
        depth: usize,
        rng: &mut Pcg,
    ) -> usize {
        let node_id = self.nodes.len();
        let first = labels[idx[0]];
        let pure = idx.iter().all(|&i| labels[i] == first);
        if pure || depth >= params.max_depth || idx.len() < params.min_samples_split {
            self.nodes.push(Node::Leaf { class: majority(labels, &idx, self.n_classes) });
            return node_id;
        }
        let d = x[0].len();
        let n_try = if params.max_features == 0 {
            ((d as f64).sqrt().ceil() as usize).clamp(1, d)
        } else {
            params.max_features.min(d)
        };
        // Find the best (feature, threshold) among random features.
        let feats = rng.sample_distinct(d, n_try);
        let mut best: Option<(usize, f64, f64)> = None; // (feature, thr, score)
        let mut sorted = idx.clone();
        for &f in &feats {
            sorted.sort_by(|&a, &b| x[a][f].partial_cmp(&x[b][f]).unwrap());
            // Sweep thresholds between consecutive distinct values.
            let mut left_counts = vec![0usize; self.n_classes];
            let mut right_counts = vec![0usize; self.n_classes];
            for &i in &sorted {
                right_counts[labels[i]] += 1;
            }
            for k in 0..sorted.len() - 1 {
                let i = sorted[k];
                left_counts[labels[i]] += 1;
                right_counts[labels[i]] -= 1;
                let (a, b) = (x[i][f], x[sorted[k + 1]][f]);
                if b - a < 1e-12 {
                    continue;
                }
                let nl = k + 1;
                let nr = sorted.len() - nl;
                let score = (nl as f64 * gini(&left_counts, nl)
                    + nr as f64 * gini(&right_counts, nr))
                    / sorted.len() as f64;
                if best.map_or(true, |(_, _, s)| score < s) {
                    best = Some((f, 0.5 * (a + b), score));
                }
            }
        }
        match best {
            None => {
                self.nodes.push(Node::Leaf { class: majority(labels, &idx, self.n_classes) });
                node_id
            }
            Some((f, thr, _)) => {
                let (left_idx, right_idx): (Vec<usize>, Vec<usize>) =
                    idx.into_iter().partition(|&i| x[i][f] <= thr);
                self.nodes.push(Node::Leaf { class: 0 }); // placeholder
                let left = self.grow(x, labels, left_idx, params, depth + 1, rng);
                let right = self.grow(x, labels, right_idx, params, depth + 1, rng);
                self.nodes[node_id] = Node::Split { feature: f, threshold: thr, left, right };
                node_id
            }
        }
    }

    /// Predict the class of one feature vector.
    pub fn predict(&self, x: &[f64]) -> usize {
        let mut cur = 0;
        loop {
            match &self.nodes[cur] {
                Node::Leaf { class } => return *class,
                Node::Split { feature, threshold, left, right } => {
                    cur = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }
}

/// Bagged random forest.
pub struct RandomForest {
    trees: Vec<DecisionTree>,
    n_classes: usize,
}

impl RandomForest {
    /// Fit on feature rows `x` with integer labels.
    pub fn fit(x: &[Vec<f64>], labels: &[usize], params: &ForestParams, rng: &mut Pcg) -> Self {
        assert_eq!(x.len(), labels.len());
        assert!(!x.is_empty(), "empty training set");
        let n_classes = labels.iter().copied().max().unwrap() + 1;
        let n = x.len();
        let trees = (0..params.n_trees)
            .map(|_| {
                // Bootstrap sample.
                let idx: Vec<usize> = (0..n).map(|_| rng.below(n)).collect();
                DecisionTree::fit(x, labels, idx, n_classes, params, rng)
            })
            .collect();
        RandomForest { trees, n_classes }
    }

    /// Majority vote over the ensemble.
    pub fn predict(&self, x: &[f64]) -> usize {
        let mut votes = vec![0usize; self.n_classes];
        for t in &self.trees {
            votes[t.predict(x)] += 1;
        }
        votes.iter().enumerate().max_by_key(|&(_, v)| *v).map(|(k, _)| k).unwrap_or(0)
    }

    /// Predict a batch.
    pub fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<usize> {
        xs.iter().map(|x| self.predict(x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics::accuracy;

    /// Two well-separated Gaussian blobs.
    fn blobs(n: usize, rng: &mut Pcg) -> (Vec<Vec<f64>>, Vec<usize>) {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let label = i % 2;
            let c = if label == 0 { -2.0 } else { 2.0 };
            x.push(vec![c + rng.normal() * 0.5, c + rng.normal() * 0.5]);
            y.push(label);
        }
        (x, y)
    }

    #[test]
    fn separable_blobs_high_accuracy() {
        let mut rng = Pcg::seed(1);
        let (xtr, ytr) = blobs(200, &mut rng);
        let (xte, yte) = blobs(100, &mut rng);
        let rf = RandomForest::fit(&xtr, &ytr, &ForestParams::default(), &mut rng);
        let acc = accuracy(&rf.predict_batch(&xte), &yte);
        assert!(acc > 0.95, "acc={acc}");
    }

    #[test]
    fn xor_needs_depth() {
        // XOR: linearly inseparable, trees must use both features.
        let mut rng = Pcg::seed(2);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..400 {
            let (a, b) = (rng.bool(0.5), rng.bool(0.5));
            x.push(vec![
                if a { 1.0 } else { 0.0 } + rng.normal() * 0.1,
                if b { 1.0 } else { 0.0 } + rng.normal() * 0.1,
            ]);
            y.push((a ^ b) as usize);
        }
        let rf = RandomForest::fit(
            &x,
            &y,
            &ForestParams { n_trees: 30, max_depth: 6, min_samples_split: 2, max_features: 2 },
            &mut rng,
        );
        let acc = accuracy(&rf.predict_batch(&x), &y);
        assert!(acc > 0.9, "acc={acc}");
    }

    #[test]
    fn multiclass() {
        let mut rng = Pcg::seed(3);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..300 {
            let label = i % 3;
            x.push(vec![label as f64 * 3.0 + rng.normal() * 0.3]);
            y.push(label);
        }
        let rf = RandomForest::fit(&x, &y, &ForestParams::default(), &mut rng);
        let acc = accuracy(&rf.predict_batch(&x), &y);
        assert!(acc > 0.95, "acc={acc}");
    }

    #[test]
    fn constant_features_degrade_gracefully() {
        let mut rng = Pcg::seed(4);
        let x = vec![vec![1.0, 1.0]; 20];
        let y: Vec<usize> = (0..20).map(|i| i % 2).collect();
        let rf = RandomForest::fit(&x, &y, &ForestParams::default(), &mut rng);
        // Cannot split; must fall back to majority-vote leaves.
        let p = rf.predict(&[1.0, 1.0]);
        assert!(p < 2);
    }
}
