//! Machine-learning substrates: deterministic RNG, random forests,
//! cross-validation, metrics, and the learnable rational-f trainer (§4.3).

pub mod dataset;
pub mod fit_rational;
pub mod metrics;
pub mod random_forest;
pub mod shapes;
pub mod wl_kernel;
pub mod rng;
