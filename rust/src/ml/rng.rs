//! Deterministic pseudo-random number generation.
//!
//! The environment is offline (no `rand` crate), so we ship a small,
//! well-tested PCG-XSH-RR 64/32 generator plus the handful of
//! distributions the rest of the library needs (uniform, normal,
//! exponential, categorical, permutation sampling). All experiment code
//! seeds explicitly so every figure/table regenerates bit-identically.

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit xorshift-rotated output.
///
/// Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation" (2014).
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Next raw 32 bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64 bits (two 32-bit draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our
    /// purposes; bias < 2^-32 is irrelevant at our sample sizes).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.uniform().max(1e-300).ln() / lambda
    }

    /// Bernoulli draw.
    pub fn bool(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut r = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct indices from `0..n` (k <= n).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        if k * 4 >= n {
            let mut p = self.permutation(n);
            p.truncate(k);
            p
        } else {
            // Rejection sampling with a small hash set is faster when k << n.
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let x = self.below(n);
                if seen.insert(x) {
                    out.push(x);
                }
            }
            out
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of uniforms in `[lo, hi)`.
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform_in(lo, hi)).collect()
    }

    /// Fork a new statistically independent generator (distinct stream).
    pub fn fork(&mut self) -> Pcg {
        Pcg::new(self.next_u64(), self.next_u64() | 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg::seed(42);
        let mut b = Pcg::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg::seed(1);
        let mut b = Pcg::seed(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg::seed(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Pcg::seed(3);
        let mean: f64 = (0..100_000).map(|_| r.uniform()).sum::<f64>() / 1e5;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::seed(11);
        let xs: Vec<f64> = (0..100_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg::seed(5);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn below_covers_all_values() {
        let mut r = Pcg::seed(6);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Pcg::seed(9);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Pcg::seed(10);
        for &(n, k) in &[(100usize, 5usize), (100, 80), (10, 10), (1000, 3)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg::seed(12);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg::seed(13);
        let mean: f64 = (0..50_000).map(|_| r.exponential(2.0)).sum::<f64>() / 5e4;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut a = Pcg::seed(1);
        let mut b = a.fork();
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
