//! Synthetic-shapes image dataset — the ImageNet/Places365 substitute for
//! the Topological-ViT experiments (Table 1 / Fig. 7).
//!
//! Eight procedurally drawn 32×32 grayscale classes with random position/
//! size jitter and pixel noise. The classes are chosen so that *spatial
//! topology* carries signal (rings vs discs, crosses vs bars, checkers vs
//! stripes): exactly the kind of structure a topological RPE mask over
//! the patch grid can exploit, which is what makes the masked-vs-unmasked
//! comparison meaningful at this scale.

use crate::ml::rng::Pcg;

/// Image side (must match python/compile/model.py IMG).
pub const IMG: usize = 32;
/// Number of classes (must match model N_CLASSES).
pub const N_CLASSES: usize = 8;

/// One labelled example.
#[derive(Clone, Debug)]
pub struct Example {
    pub pixels: Vec<f32>, // IMG*IMG, roughly zero-mean
    pub label: usize,
}

/// Draw one example of the given class.
pub fn draw(label: usize, rng: &mut Pcg) -> Example {
    assert!(label < N_CLASSES);
    let mut img = vec![0.0f32; IMG * IMG];
    let cx = rng.uniform_in(12.0, 20.0);
    let cy = rng.uniform_in(12.0, 20.0);
    let r = rng.uniform_in(6.0, 10.0);
    let set = |img: &mut Vec<f32>, x: usize, y: usize, v: f32| {
        img[y * IMG + x] = v;
    };
    for y in 0..IMG {
        for x in 0..IMG {
            let dx = x as f64 - cx;
            let dy = y as f64 - cy;
            let dist = (dx * dx + dy * dy).sqrt();
            let inside = match label {
                // 0: filled disc
                0 => dist < r,
                // 1: ring
                1 => dist < r && dist > r - 2.5,
                // 2: filled square
                2 => dx.abs() < r * 0.8 && dy.abs() < r * 0.8,
                // 3: hollow square
                3 => {
                    let (ax, ay) = (dx.abs(), dy.abs());
                    ax < r * 0.8 && ay < r * 0.8 && (ax > r * 0.8 - 2.5 || ay > r * 0.8 - 2.5)
                }
                // 4: plus / cross
                4 => (dx.abs() < 1.8 || dy.abs() < 1.8) && dist < r,
                // 5: diagonal X
                5 => ((dx - dy).abs() < 2.2 || (dx + dy).abs() < 2.2) && dist < r,
                // 6: horizontal stripes
                6 => (y / 4) % 2 == 0 && dist < r,
                // 7: checkerboard patch
                _ => ((x / 4) + (y / 4)) % 2 == 0 && dist < r,
            };
            if inside {
                set(&mut img, x, y, 1.0);
            }
        }
    }
    // Pixel noise + global normalisation.
    for v in img.iter_mut() {
        *v += 0.15 * rng.normal() as f32;
        *v -= 0.15; // rough mean-centering
    }
    Example { pixels: img, label }
}

/// A balanced shuffled dataset of `per_class·N_CLASSES` examples.
pub fn dataset(per_class: usize, rng: &mut Pcg) -> Vec<Example> {
    let mut out = Vec::with_capacity(per_class * N_CLASSES);
    for label in 0..N_CLASSES {
        for _ in 0..per_class {
            out.push(draw(label, rng));
        }
    }
    rng.shuffle(&mut out);
    out
}

/// Pack `batch` examples starting at `offset` (wrapping) into flat
/// buffers for the runtime.
pub fn pack_batch(data: &[Example], offset: usize, batch: usize) -> (Vec<f32>, Vec<i32>) {
    let mut images = Vec::with_capacity(batch * IMG * IMG);
    let mut labels = Vec::with_capacity(batch);
    for k in 0..batch {
        let ex = &data[(offset + k) % data.len()];
        images.extend_from_slice(&ex.pixels);
        labels.push(ex.label as i32);
    }
    (images, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_have_correct_size_and_signal() {
        let mut rng = Pcg::seed(1);
        for label in 0..N_CLASSES {
            let ex = draw(label, &mut rng);
            assert_eq!(ex.pixels.len(), IMG * IMG);
            // Some foreground pixels must be clearly lit.
            let lit = ex.pixels.iter().filter(|&&v| v > 0.5).count();
            assert!(lit > 10, "class {label}: only {lit} lit pixels");
        }
    }

    #[test]
    fn classes_differ_in_expectation() {
        // Mean images of disc vs ring must differ substantially.
        let mut rng = Pcg::seed(2);
        let mean_img = |label: usize, rng: &mut Pcg| -> Vec<f32> {
            let mut acc = vec![0.0f32; IMG * IMG];
            for _ in 0..32 {
                for (a, p) in acc.iter_mut().zip(draw(label, rng).pixels) {
                    *a += p / 32.0;
                }
            }
            acc
        };
        let disc = mean_img(0, &mut rng);
        let ring = mean_img(1, &mut rng);
        let diff: f32 =
            disc.iter().zip(&ring).map(|(a, b)| (a - b).abs()).sum::<f32>() / (IMG * IMG) as f32;
        assert!(diff > 0.05, "diff={diff}");
    }

    #[test]
    fn dataset_balanced_and_shuffled() {
        let mut rng = Pcg::seed(3);
        let ds = dataset(10, &mut rng);
        assert_eq!(ds.len(), 80);
        for c in 0..N_CLASSES {
            assert_eq!(ds.iter().filter(|e| e.label == c).count(), 10);
        }
        // Shuffled: the first 8 are unlikely to be 8 distinct ascending labels.
        let ascending = ds.windows(2).take(16).all(|w| w[0].label <= w[1].label);
        assert!(!ascending);
    }

    #[test]
    fn pack_batch_wraps() {
        let mut rng = Pcg::seed(4);
        let ds = dataset(1, &mut rng); // 8 examples
        let (img, lab) = pack_batch(&ds, 6, 4);
        assert_eq!(img.len(), 4 * IMG * IMG);
        assert_eq!(lab.len(), 4);
        assert_eq!(lab[2], ds[0].label as i32); // wrapped
    }
}
