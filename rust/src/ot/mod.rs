//! Optimal transport substrates: entropic OT via Sinkhorn with FTFI
//! kernel multiplications (§1 application 2) and Gromov–Wasserstein
//! discrepancy via conditional gradient with FTFI replacing the dense
//! cost-matrix products (Appendix D.2, Fig. 10).

pub mod gw;
pub mod sinkhorn;
