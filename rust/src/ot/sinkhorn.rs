//! Entropic optimal transport on graph metrics via Sinkhorn iterations.
//!
//! The Gibbs kernel `K = exp(-dist(i,j)/ε)` is an `f`-distance matrix
//! with `f(x) = e^{-x/ε}` — exactly the 0-cordial exponential class — so
//! each Sinkhorn iteration's `K·v` / `Kᵀ·u` products run through FTFI in
//! near-linear time instead of `O(N²)` (§1, application 2).

use crate::ftfi::functions::FDist;
use crate::ftfi::{FtfiError, PreparedIntegrator, TreeFieldIntegrator};
use crate::linalg::matrix::Matrix;
use crate::tree::Tree;
use std::fmt;

/// Result of a Sinkhorn solve.
#[derive(Debug)]
pub struct SinkhornResult {
    /// Left scaling.
    pub u: Vec<f64>,
    /// Right scaling.
    pub v: Vec<f64>,
    /// Entropic transport cost `Σ_{ij} Π_ij · dist(i,j)`.
    pub cost: f64,
    pub iterations: usize,
    pub marginal_error: f64,
}

/// Typed failure surface of the Sinkhorn solver: malformed marginals and
/// kernel (field-integration) failures surface as errors instead of
/// aborting the solve — the same rule as the rest of the FTFI stack
/// (anything reachable from user input is an error, panics are for
/// internal invariants).
#[derive(Debug, Clone, PartialEq)]
pub enum SinkhornError {
    /// A marginal's length does not match the kernel's vertex count.
    MarginalShape { expected: usize, got: usize },
    /// A kernel application failed — carries the typed [`FtfiError`]
    /// (e.g. `ShapeMismatch` for a scaling vector of the wrong length).
    Kernel(FtfiError),
}

impl fmt::Display for SinkhornError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SinkhornError::MarginalShape { expected, got } => write!(
                f,
                "marginal length {got} does not match the kernel's {expected} vertices"
            ),
            SinkhornError::Kernel(e) => write!(f, "kernel application failed: {e}"),
        }
    }
}

impl std::error::Error for SinkhornError {}

impl From<FtfiError> for SinkhornError {
    fn from(e: FtfiError) -> Self {
        SinkhornError::Kernel(e)
    }
}

/// Abstract kernel multiplication used by the solver (lets the dense
/// baseline and the FTFI path share the iteration loop). Applications
/// are fallible: a scaling vector of the wrong length is a typed
/// [`FtfiError::ShapeMismatch`], never a panic.
pub trait KernelOp {
    fn apply(&self, v: &[f64]) -> Result<Vec<f64>, FtfiError>;
    fn n(&self) -> usize;
    /// `Σ_{ij} u_i·K_ij·dist_ij·v_j` — the transport cost functional.
    fn cost(&self, u: &[f64], v: &[f64]) -> Result<f64, FtfiError>;
}

/// Dense kernel baseline (materialises K and K⊙D).
pub struct DenseKernel {
    k: Matrix,
    kd: Matrix,
}

impl DenseKernel {
    pub fn new(tree: &Tree, eps: f64) -> Self {
        let n = tree.n();
        let d = tree.all_pairs();
        let k = Matrix::from_vec(n, n, d.iter().map(|&x| (-x / eps).exp()).collect());
        let kd =
            Matrix::from_vec(n, n, d.iter().map(|&x| (-x / eps).exp() * x).collect());
        DenseKernel { k, kd }
    }
}

impl KernelOp for DenseKernel {
    fn apply(&self, v: &[f64]) -> Result<Vec<f64>, FtfiError> {
        if v.len() != self.k.rows() {
            return Err(FtfiError::ShapeMismatch { expected: self.k.rows(), got: v.len() });
        }
        Ok(self.k.matvec(v))
    }
    fn n(&self) -> usize {
        self.k.rows()
    }
    fn cost(&self, u: &[f64], v: &[f64]) -> Result<f64, FtfiError> {
        let n = self.k.rows();
        if u.len() != n {
            return Err(FtfiError::ShapeMismatch { expected: n, got: u.len() });
        }
        if v.len() != n {
            return Err(FtfiError::ShapeMismatch { expected: n, got: v.len() });
        }
        let kdv = self.kd.matvec(v);
        Ok(u.iter().zip(&kdv).map(|(a, b)| a * b).sum())
    }
}

/// FTFI-backed kernel: `K·v` through the tree integrator with
/// `f(x) = e^{-x/ε}`; the cost functional uses `f(x) = x·e^{-x/ε}`
/// (a 0-cordial poly×exp product — still fast). Both functions are
/// frozen into [`PreparedIntegrator`] handles at construction, so the
/// Sinkhorn iteration loop — the paper's canonical repeated-integration
/// workload — never re-plans a cross block.
pub struct FtfiKernel<'a> {
    kernel: PreparedIntegrator<'a>,
    cost: PreparedIntegrator<'a>,
}

impl<'a> FtfiKernel<'a> {
    /// Prepare both kernels on the caller's integrator. With the default
    /// policy this cannot fail (the exponential classes are 0-cordial),
    /// but a caller-configured forced strategy that does not apply
    /// surfaces here as a typed error rather than a panic.
    pub fn new(
        tfi: &'a TreeFieldIntegrator,
        eps: f64,
    ) -> Result<Self, crate::ftfi::FtfiError> {
        let f_kernel = FDist::Exponential { lambda: -1.0 / eps, scale: 1.0 };
        let f_cost = FDist::PolyExp { coeffs: vec![0.0, 1.0], lambda: -1.0 / eps };
        Ok(FtfiKernel { kernel: tfi.prepare(&f_kernel)?, cost: tfi.prepare(&f_cost)? })
    }
}

impl KernelOp for FtfiKernel<'_> {
    fn apply(&self, v: &[f64]) -> Result<Vec<f64>, FtfiError> {
        // A wrong-length scaling vector surfaces as the integrator's
        // typed ShapeMismatch instead of aborting the solver.
        self.kernel.integrate_vec(v)
    }
    fn n(&self) -> usize {
        self.kernel.n()
    }
    fn cost(&self, u: &[f64], v: &[f64]) -> Result<f64, FtfiError> {
        if u.len() != self.kernel.n() {
            return Err(FtfiError::ShapeMismatch { expected: self.kernel.n(), got: u.len() });
        }
        let kdv = self.cost.integrate_vec(v)?;
        Ok(u.iter().zip(&kdv).map(|(a, b)| a * b).sum())
    }
}

/// Run Sinkhorn until the marginal error drops below `tol` (or max
/// iterations). `a`, `b` are the source/target marginals (must sum to
/// 1). Malformed marginals and kernel failures return a typed
/// [`SinkhornError`] instead of aborting the solver.
pub fn sinkhorn(
    kernel: &impl KernelOp,
    a: &[f64],
    b: &[f64],
    tol: f64,
    max_iter: usize,
) -> Result<SinkhornResult, SinkhornError> {
    let n = kernel.n();
    if a.len() != n {
        return Err(SinkhornError::MarginalShape { expected: n, got: a.len() });
    }
    if b.len() != n {
        return Err(SinkhornError::MarginalShape { expected: n, got: b.len() });
    }
    let mut u = vec![1.0; n];
    let mut v = vec![1.0; n];
    let mut err = f64::INFINITY;
    let mut iters = 0;
    for it in 0..max_iter {
        // u = a ./ (K v) ; v = b ./ (Kᵀ u) — K symmetric here.
        let kv = kernel.apply(&v)?;
        for i in 0..n {
            u[i] = a[i] / kv[i].max(1e-300);
        }
        let ku = kernel.apply(&u)?;
        for j in 0..n {
            v[j] = b[j] / ku[j].max(1e-300);
        }
        // Marginal violation on the row side.
        let kv = kernel.apply(&v)?;
        err = (0..n).map(|i| (u[i] * kv[i] - a[i]).abs()).sum();
        iters = it + 1;
        if err < tol {
            break;
        }
    }
    let cost = kernel.cost(&u, &v)?;
    Ok(SinkhornResult { u, v, cost, iterations: iters, marginal_error: err })
}

/// Uniform marginal helper.
pub fn uniform_marginal(n: usize) -> Vec<f64> {
    vec![1.0 / n as f64; n]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::ml::rng::Pcg;

    #[test]
    fn ftfi_and_dense_kernels_agree() {
        let mut rng = Pcg::seed(1);
        let tree = generators::random_tree(60, 0.1, 1.0, &mut rng);
        let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
        let dense = DenseKernel::new(&tree, 0.5);
        let fast = FtfiKernel::new(&tfi, 0.5).unwrap();
        let v = rng.uniform_vec(60, 0.1, 1.0);
        let kd = dense.apply(&v).unwrap();
        let kf = fast.apply(&v).unwrap();
        for (a, b) in kd.iter().zip(&kf) {
            assert!((a - b).abs() < 1e-8 * (1.0 + a.abs()), "{a} vs {b}");
        }
        let u = rng.uniform_vec(60, 0.1, 1.0);
        let cd = dense.cost(&u, &v).unwrap();
        let cf = fast.cost(&u, &v).unwrap();
        assert!((cd - cf).abs() < 1e-7 * (1.0 + cd.abs()));
    }

    #[test]
    fn sinkhorn_converges_to_marginals() {
        let mut rng = Pcg::seed(2);
        let tree = generators::random_tree(40, 0.2, 1.0, &mut rng);
        let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
        let kernel = FtfiKernel::new(&tfi, 0.8).unwrap();
        let a = uniform_marginal(40);
        let mut b = rng.uniform_vec(40, 0.5, 1.5);
        let s: f64 = b.iter().sum();
        b.iter_mut().for_each(|x| *x /= s);
        let res = sinkhorn(&kernel, &a, &b, 1e-9, 500).unwrap();
        assert!(res.marginal_error < 1e-8, "err={}", res.marginal_error);
        assert!(res.cost >= 0.0);
    }

    #[test]
    fn identical_marginals_small_cost_at_small_eps() {
        // With a == b the optimal plan is near-diagonal; entropic cost
        // shrinks as ε decreases.
        let mut rng = Pcg::seed(3);
        let tree = generators::random_tree(30, 0.5, 1.0, &mut rng);
        let a = uniform_marginal(30);
        let costs: Vec<f64> = [1.0, 0.25]
            .iter()
            .map(|&eps| {
                let dense = DenseKernel::new(&tree, eps);
                sinkhorn(&dense, &a, &a, 1e-10, 1000).unwrap().cost
            })
            .collect();
        assert!(costs[1] < costs[0], "{costs:?}");
    }

    /// The former panic sites: malformed marginals / scaling vectors
    /// surface as typed errors (the integrator's `ShapeMismatch` routed
    /// through `SinkhornError`) instead of aborting the solver.
    #[test]
    fn malformed_inputs_are_typed_errors_not_panics() {
        let mut rng = Pcg::seed(4);
        let tree = generators::random_tree(20, 0.2, 1.0, &mut rng);
        let tfi = TreeFieldIntegrator::builder(&tree).build().unwrap();
        let kernel = FtfiKernel::new(&tfi, 0.5).unwrap();
        // Wrong-length marginal: rejected up front.
        let a = uniform_marginal(19);
        let b = uniform_marginal(20);
        assert_eq!(
            sinkhorn(&kernel, &a, &b, 1e-9, 10).err(),
            Some(SinkhornError::MarginalShape { expected: 20, got: 19 })
        );
        assert_eq!(
            sinkhorn(&kernel, &b, &a, 1e-9, 10).err(),
            Some(SinkhornError::MarginalShape { expected: 20, got: 19 })
        );
        // Wrong-length kernel application: the typed FtfiError flows
        // through (this is the path that used to `expect`-abort).
        assert_eq!(
            kernel.apply(&[1.0; 19]).err(),
            Some(FtfiError::ShapeMismatch { expected: 20, got: 19 })
        );
        assert!(matches!(
            kernel.cost(&[1.0; 19], &[1.0; 20]).err(),
            Some(FtfiError::ShapeMismatch { expected: 20, got: 19 })
        ));
        // The dense baseline obeys the same contract.
        let dense = DenseKernel::new(&tree, 0.5);
        assert!(dense.apply(&[1.0; 21]).is_err());
        // A well-formed solve still succeeds after the failed attempts.
        let ok = sinkhorn(&kernel, &b, &b, 1e-6, 50).unwrap();
        assert!(ok.marginal_error.is_finite());
    }
}
