//! Gromov–Wasserstein discrepancy between two tree metrics via
//! conditional gradient (Frank–Wolfe), with the inner field-integration
//! products `C₁·T·C₂` computed either densely (the POT-style baseline) or
//! through FTFI (Appendix D.2 / Fig. 10 — "FTFI can be injected
//! seamlessly in place of the FMM algorithms").
//!
//! With the square loss, the GW objective decomposes (Peyré & Cuturi) as
//! `const(p,q) − 2·⟨C₁ T C₂, T⟩`, and all appearances of `C₁`/`C₂` are
//! `f`-distance-matrix products with multi-channel fields: `f(x) = x`
//! (rank-2 separable) and `f(x) = x²` (rank-3) — both 0-cordial, so FTFI
//! runs them in near-linear time.

use crate::ftfi::functions::FDist;
use crate::ftfi::{FtfiError, PreparedIntegrator, TreeFieldIntegrator};
use crate::linalg::matrix::Matrix;
use crate::tree::Tree;

/// Which backend computes the `C·X` products.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GwBackend {
    /// Materialise the distance matrices (O(n²) each) and use dense GEMM.
    Dense,
    /// FTFI integrations on the trees.
    Ftfi,
}

/// Solver configuration.
#[derive(Clone, Debug)]
pub struct GwParams {
    pub max_iter: usize,
    /// Entropic regularisation of the inner linear-OT direction solve.
    pub inner_eps: f64,
    pub inner_iters: usize,
    pub tol: f64,
}

impl Default for GwParams {
    fn default() -> Self {
        GwParams { max_iter: 50, inner_eps: 0.005, inner_iters: 300, tol: 1e-9 }
    }
}

/// Result of a GW solve.
#[derive(Debug)]
pub struct GwResult {
    pub plan: Matrix,
    pub discrepancy: f64,
    pub iterations: usize,
    /// Wall-clock seconds spent inside field-integration products — the
    /// quantity Fig. 10 compares across backends.
    pub integration_seconds: f64,
}

/// Internal: one side's distance operator. The FTFI variant holds
/// *prepared* handles for both kernels (`f(x)=x`, `f(x)=x²`) — the
/// conditional-gradient loop integrates with the same two functions on
/// every iteration, so plans are frozen once up front.
enum SideOp<'a> {
    Dense { d: Matrix, d2: Matrix },
    Ftfi { id: PreparedIntegrator<'a>, sq: PreparedIntegrator<'a> },
}

impl SideOp<'_> {
    /// `M_f · X` for f(x)=x.
    fn apply_id(&self, x: &Matrix) -> Result<Matrix, FtfiError> {
        match self {
            SideOp::Dense { d, .. } => Ok(d.matmul(x)),
            SideOp::Ftfi { id, .. } => id.integrate(x),
        }
    }
    /// `M_f · X` for f(x)=x².
    fn apply_sq(&self, x: &Matrix) -> Result<Matrix, FtfiError> {
        match self {
            SideOp::Dense { d2, .. } => Ok(d2.matmul(x)),
            SideOp::Ftfi { sq, .. } => sq.integrate(x),
        }
    }
}

/// Inner direction solve: `min_T ⟨G, T⟩` over the transport polytope via
/// entropic Sinkhorn on the (dense) gradient matrix.
fn sinkhorn_direction(g: &Matrix, p: &[f64], q: &[f64], eps: f64, iters: usize) -> Matrix {
    let (n, m) = (g.rows(), g.cols());
    // Normalise the cost scale so eps behaves uniformly.
    let gmax = g.data().iter().fold(0.0f64, |acc, &x| acc.max(x.abs())).max(1e-12);
    let k = Matrix::from_fn(n, m, |i, j| (-g.get(i, j) / (eps * gmax)).exp().max(1e-300));
    let mut u = vec![1.0; n];
    let mut v = vec![1.0; m];
    for _ in 0..iters {
        let kv = k.matvec(&v);
        for i in 0..n {
            u[i] = p[i] / kv[i].max(1e-300);
        }
        let ktu = k.matvec_t(&u);
        for j in 0..m {
            v[j] = q[j] / ktu[j].max(1e-300);
        }
    }
    Matrix::from_fn(n, m, |i, j| u[i] * k.get(i, j) * v[j])
}

/// Solve GW between the metrics of `ta` and `tb` with marginals `p`, `q`.
///
/// Fails with [`FtfiError::ShapeMismatch`] when a marginal's length does
/// not match its tree's vertex count (and propagates any FTFI planning
/// error from the chosen backend).
pub fn gromov_wasserstein(
    ta: &Tree,
    tb: &Tree,
    p: &[f64],
    q: &[f64],
    backend: GwBackend,
    params: &GwParams,
) -> Result<GwResult, FtfiError> {
    let n = ta.n();
    let m = tb.n();
    if p.len() != n {
        return Err(FtfiError::ShapeMismatch { expected: n, got: p.len() });
    }
    if q.len() != m {
        return Err(FtfiError::ShapeMismatch { expected: m, got: q.len() });
    }

    // Build backends (preprocessing cost included in integration time for
    // the dense baseline, since materialisation IS its integration step).
    let mut integration_seconds = 0.0;
    let t0 = std::time::Instant::now();
    let tfia;
    let tfib;
    let (opa, opb) = match backend {
        GwBackend::Dense => {
            let da = ta.all_pairs();
            let db = tb.all_pairs();
            let d2a: Vec<f64> = da.iter().map(|&x| x * x).collect();
            let d2b: Vec<f64> = db.iter().map(|&x| x * x).collect();
            (
                SideOp::Dense {
                    d: Matrix::from_vec(n, n, da),
                    d2: Matrix::from_vec(n, n, d2a),
                },
                SideOp::Dense {
                    d: Matrix::from_vec(m, m, db),
                    d2: Matrix::from_vec(m, m, d2b),
                },
            )
        }
        GwBackend::Ftfi => {
            let f_id = FDist::Identity;
            let f_sq = FDist::Polynomial(vec![0.0, 0.0, 1.0]);
            tfia = TreeFieldIntegrator::builder(ta).build()?;
            tfib = TreeFieldIntegrator::builder(tb).build()?;
            (
                SideOp::Ftfi { id: tfia.prepare(&f_id)?, sq: tfia.prepare(&f_sq)? },
                SideOp::Ftfi { id: tfib.prepare(&f_id)?, sq: tfib.prepare(&f_sq)? },
            )
        }
    };
    integration_seconds += t0.elapsed().as_secs_f64();

    // Constant part of the square-loss decomposition:
    // cst = (C₁∘C₁)·p·1ᵀ + 1·qᵀ·(C₂∘C₂)ᵀ.
    let t0 = std::time::Instant::now();
    let c1sq_p = opa.apply_sq(&Matrix::from_vec(n, 1, p.to_vec()))?;
    let c2sq_q = opb.apply_sq(&Matrix::from_vec(m, 1, q.to_vec()))?;
    integration_seconds += t0.elapsed().as_secs_f64();

    // `C₁·T·C₂` through the chosen backend; T is n×m.
    let mut apply_c1_t_c2 = |t: &Matrix| -> Result<Matrix, FtfiError> {
        let t0 = std::time::Instant::now();
        // (T·C₂) = (C₂·Tᵀ)ᵀ — C₂ symmetric.
        let tc2 = opb.apply_id(&t.transpose())?.transpose();
        let out = opa.apply_id(&tc2)?;
        integration_seconds += t0.elapsed().as_secs_f64();
        Ok(out)
    };

    let loss = |t: &Matrix, c1tc2: &Matrix| -> f64 {
        // Σ_ij cst_ij T_ij − 2 ⟨C₁TC₂, T⟩ with cst rank-1 structure.
        let mut acc = 0.0;
        for i in 0..n {
            for j in 0..m {
                let cst = c1sq_p.get(i, 0) + c2sq_q.get(j, 0);
                acc += (cst - 2.0 * c1tc2.get(i, j)) * t.get(i, j);
            }
        }
        acc
    };

    // Initial plan: independent coupling p·qᵀ with a deterministic
    // symmetry-breaking perturbation, renormalised to the row marginals.
    // (Conditional gradient from the exactly-uniform coupling stalls at a
    // symmetric saddle point of the non-convex GW objective.)
    let mut t = Matrix::from_fn(n, m, |i, j| {
        let h = ((i.wrapping_mul(2654435761) ^ j.wrapping_mul(40503)) % 1000) as f64 / 1000.0;
        p[i] * q[j] * (1.0 + 0.25 * (h - 0.5))
    });
    for i in 0..n {
        let row_sum: f64 = t.row(i).iter().sum();
        let c = p[i] / row_sum.max(1e-300);
        for v in t.row_mut(i) {
            *v *= c;
        }
    }
    let mut c1tc2 = apply_c1_t_c2(&t)?;
    let mut cur_loss = loss(&t, &c1tc2);
    let mut iterations = 0;
    for it in 0..params.max_iter {
        iterations = it + 1;
        // Gradient: cst − 2·C₁TC₂ (up to the symmetrisation factor).
        let grad = Matrix::from_fn(n, m, |i, j| {
            c1sq_p.get(i, 0) + c2sq_q.get(j, 0) - 2.0 * c1tc2.get(i, j)
        });
        let dir = sinkhorn_direction(&grad, p, q, params.inner_eps, params.inner_iters);
        // Quadratic line search on T + α(D−T), α ∈ [0,1]: evaluate the
        // true objective at three points and minimise the fitted parabola.
        let mut tryat = |alpha: f64| -> Result<(Matrix, Matrix, f64), FtfiError> {
            let mut cand = t.clone();
            cand.scale(1.0 - alpha);
            cand.axpy(alpha, &dir);
            let c = apply_c1_t_c2(&cand)?;
            let l = loss(&cand, &c);
            Ok((cand, c, l))
        };
        let (t_half, c_half, l_half) = tryat(0.5)?;
        let (t_one, c_one, l_one) = tryat(1.0)?;
        // Parabola through (0, cur), (0.5, half), (1, one). When the
        // segment is concave (a ≤ 0) the minimum is at an endpoint, so
        // always compare the interior stationary point against both
        // evaluated endpoints and keep the best improving candidate.
        let a = 2.0 * (cur_loss - 2.0 * l_half + l_one);
        let b = -3.0 * cur_loss + 4.0 * l_half - l_one;
        let mut candidates = vec![(t_half, c_half, l_half), (t_one, c_one, l_one)];
        if a > 1e-15 {
            let alpha_star = (-b / (2.0 * a)).clamp(0.0, 1.0);
            let interior = alpha_star > 1e-9
                && (alpha_star - 0.5).abs() > 1e-9
                && (alpha_star - 1.0).abs() > 1e-9;
            if interior {
                candidates.push(tryat(alpha_star)?);
            }
        }
        // total_cmp: losses can be NaN only if the input weights were,
        // and a total order keeps the selection deterministic either way.
        candidates.sort_by(|x, y| x.2.total_cmp(&y.2));
        let mut improved = false;
        if let Some((tc, cc, lc)) = candidates.into_iter().next() {
            if lc < cur_loss - params.tol * (1.0 + cur_loss.abs()) {
                t = tc;
                c1tc2 = cc;
                cur_loss = lc;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
    Ok(GwResult { plan: t, discrepancy: cur_loss.max(0.0), iterations, integration_seconds })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::ml::rng::Pcg;
    use crate::ot::sinkhorn::uniform_marginal;

    #[test]
    fn backends_agree() {
        let mut rng = Pcg::seed(1);
        let ta = generators::random_tree(24, 0.2, 1.0, &mut rng);
        let tb = generators::random_tree(20, 0.2, 1.0, &mut rng);
        let p = uniform_marginal(24);
        let q = uniform_marginal(20);
        let params = GwParams::default();
        let rd = gromov_wasserstein(&ta, &tb, &p, &q, GwBackend::Dense, &params).unwrap();
        let rf = gromov_wasserstein(&ta, &tb, &p, &q, GwBackend::Ftfi, &params).unwrap();
        let rel = (rd.discrepancy - rf.discrepancy).abs() / (1.0 + rd.discrepancy);
        assert!(rel < 1e-6, "dense {} vs ftfi {}", rd.discrepancy, rf.discrepancy);
    }

    #[test]
    fn isomorphic_trees_near_zero() {
        // GW between a tree and itself should be (near) zero.
        let mut rng = Pcg::seed(2);
        let t = generators::random_tree(16, 0.5, 1.0, &mut rng);
        let p = uniform_marginal(16);
        let r = gromov_wasserstein(&t, &t, &p, &p, GwBackend::Dense, &GwParams::default()).unwrap();
        // Entropic inner solves keep it from exact zero; expect small.
        let scale: f64 = t.all_pairs().iter().map(|d| d * d).sum::<f64>() / (16.0 * 16.0);
        assert!(r.discrepancy < 0.35 * scale, "gw={} scale={scale}", r.discrepancy);
    }

    #[test]
    fn distinguishes_path_from_star() {
        // A path and a star of the same size are metrically very
        // different; GW should be clearly larger than self-distance.
        let path = Tree::path(&vec![1.0; 15]);
        let star_edges: Vec<(u32, u32, f64)> = (1..16).map(|v| (0, v, 1.0)).collect();
        let star = Tree::from_edges(16, &star_edges);
        let p = uniform_marginal(16);
        let params = GwParams::default();
        let self_d =
            gromov_wasserstein(&path, &path, &p, &p, GwBackend::Dense, &params).unwrap();
        let cross =
            gromov_wasserstein(&path, &star, &p, &p, GwBackend::Dense, &params).unwrap();
        assert!(
            cross.discrepancy > 2.0 * self_d.discrepancy,
            "cross {} vs self {}",
            cross.discrepancy,
            self_d.discrepancy
        );
    }

    #[test]
    fn plan_is_a_coupling() {
        let mut rng = Pcg::seed(3);
        let ta = generators::random_tree(12, 0.5, 1.0, &mut rng);
        let tb = generators::random_tree(14, 0.5, 1.0, &mut rng);
        let p = uniform_marginal(12);
        let q = uniform_marginal(14);
        let r = gromov_wasserstein(&ta, &tb, &p, &q, GwBackend::Ftfi, &GwParams::default()).unwrap();
        // Marginals approximately honoured (entropic inner solves).
        for i in 0..12 {
            let row: f64 = (0..14).map(|j| r.plan.get(i, j)).sum();
            assert!((row - p[i]).abs() < 0.02, "row {i}: {row}");
        }
        for j in 0..14 {
            let col: f64 = (0..12).map(|i| r.plan.get(i, j)).sum();
            assert!((col - q[j]).abs() < 0.02, "col {j}: {col}");
        }
    }
}
