//! # ftfi — Fast Tree-Field Integrators
//!
//! A production-grade reproduction of *"Fast Tree-Field Integrators:
//! From Low Displacement Rank to Topological Transformers"*
//! (Choromanski et al., NeurIPS 2024).
//!
//! The library provides:
//!
//! - exact polylog-linear integration of tensor fields on weighted trees
//!   ([`ftfi::TreeFieldIntegrator`]) and, via MST metrics or randomized
//!   FRT/Bartal tree ensembles, on general graphs
//!   ([`ftfi::GraphFieldIntegrator`], [`ftfi::EnsembleFieldIntegrator`]),
//!   behind a fallible builder / prepare / integrate lifecycle with the
//!   typed [`ftfi::FtfiError`] taxonomy and the unified
//!   [`ftfi::FieldIntegrator`] trait;
//! - prepared-plan handles ([`ftfi::PreparedIntegrator`]) that build the
//!   per-block cross plans once per `(tree, f)` and amortise them over
//!   any number of integrations — the serving / Sinkhorn / GW pattern;
//! - streaming delta integration ([`ftfi::StreamingIntegrator`], the
//!   `integrate_delta*` family): a k-row field update refreshes the
//!   cached integral exactly in O(k·polylog(n)·d + n·d) by linearity,
//!   with a configurable bit-exact full-refresh drift policy — the
//!   online/interactive serving scenario (`serve --streaming`) — plus
//!   O(log n) in-place edge re-plans for dynamic metrics
//!   ([`ftfi::SharedPlans`], `TreeFieldIntegrator::replan_edge`,
//!   `integrate --replan-edges`);
//! - the full cordial-function multiplier suite (outer-product, Hankel/
//!   FFT, rational multipoint, Cauchy-LDR, Vandermonde) plus the RFF and
//!   NU-FFT approximate extensions;
//! - a std-only scoped work pool ([`runtime::pool::WorkPool`]) that
//!   parallelises the IT recursion, plan preparation and batch
//!   integration across threads with **bit-identical-to-serial** outputs
//!   (knobs: builder `.threads(..)`, CLI `--threads`, env
//!   `FTFI_THREADS`, config `integrator.threads`);
//! - the paper's application stack: mesh interpolation, graph
//!   classification (eigenfeatures + random forest), learnable rational
//!   `f`-distance matrices, Gromov–Wasserstein speedups, and a batching
//!   inference coordinator that serves field integrations directly and
//!   — behind the `pjrt` cargo feature — Topological Vision Transformers
//!   through AOT-compiled JAX/Pallas models (PJRT).
//!
//! See `DESIGN.md` for the system inventory, the builder/prepare/
//! integrate lifecycle, the error taxonomy and the numerics notes.

// Unsafe inventory (see DESIGN.md "Verification & static analysis"):
// the crate is `unsafe`-free except for two explicitly allowed sites —
// the counting test allocator in `bench_util` and the loom-only scoped
// spawn shim in `sync`.
#![deny(unsafe_code)]

pub mod bench_util;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod ftfi;
pub mod graph;
pub mod linalg;
pub mod ml;
pub mod ot;
pub mod runtime;
pub mod sync;
pub mod tree;

pub use ftfi::functions::FDist;
pub use ftfi::{
    EnsembleFieldIntegrator, EnsembleMethod, FieldIntegrator, FtfiError, GraphFieldIntegrator,
    Precision, PreparedIntegrator, ReplanStats, SharedPlans, StreamingIntegrator,
    TreeFieldIntegrator,
};
pub use graph::Graph;
pub use linalg::matrix::Matrix;
pub use runtime::pool::WorkPool;
pub use tree::Tree;
