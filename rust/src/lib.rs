//! # ftfi — Fast Tree-Field Integrators
//!
//! A production-grade reproduction of *"Fast Tree-Field Integrators:
//! From Low Displacement Rank to Topological Transformers"*
//! (Choromanski et al., NeurIPS 2024).
//!
//! The library provides:
//!
//! - exact polylog-linear integration of tensor fields on weighted trees
//!   ([`ftfi::TreeFieldIntegrator`]) and, via MST metrics, on general
//!   graphs ([`ftfi::GraphFieldIntegrator`]);
//! - the full cordial-function multiplier suite (outer-product, Hankel/
//!   FFT, rational multipoint, Cauchy-LDR, Vandermonde) plus the RFF and
//!   NU-FFT approximate extensions;
//! - the paper's application stack: mesh interpolation, graph
//!   classification (eigenfeatures + random forest), learnable rational
//!   `f`-distance matrices, Gromov–Wasserstein speedups, and Topological
//!   Vision Transformers served through a rust coordinator over AOT-
//!   compiled JAX/Pallas models (PJRT).
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md`
//! for the paper-vs-measured record of every table and figure.

pub mod bench_util;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod ftfi;
pub mod graph;
pub mod linalg;
pub mod ml;
pub mod ot;
pub mod runtime;
pub mod tree;

pub use ftfi::functions::FDist;
pub use ftfi::{GraphFieldIntegrator, TreeFieldIntegrator};
pub use graph::Graph;
pub use linalg::matrix::Matrix;
pub use tree::Tree;
