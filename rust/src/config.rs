//! Configuration system: a small INI/TOML-subset parser (offline — no
//! serde/toml crates) plus typed config structs for the serving
//! coordinator and the experiment drivers. Files look like:
//!
//! ```text
//! # comment
//! [server]
//! batch_size = 8
//! batch_timeout_ms = 5
//!
//! [model]
//! artifact = "artifacts/topvit_b8.hlo.txt"
//! ```

use crate::ftfi::cordial::{CrossPolicy, Strategy};
use crate::ftfi::ensemble::EnsembleMethod;
use crate::ftfi::FtfiError;
use crate::linalg::lanes::Precision;
use std::collections::HashMap;

/// Parsed config: `section.key -> value` strings.
#[derive(Debug, Default, Clone)]
pub struct Config {
    values: HashMap<String, String>,
}

impl Config {
    /// Parse from text. Later keys override earlier ones.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut values = HashMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(stripped) = line.strip_prefix('[') {
                let name = stripped
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = v.trim().trim_matches('"').to_string();
            values.insert(key, val);
        }
        Ok(Config { values })
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Config::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.get(key)
            .map(|v| matches!(v, "true" | "1" | "yes" | "on"))
            .unwrap_or(default)
    }

    /// Override a value (CLI flags do this on top of file configs).
    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }
}

/// Typed serving configuration (coordinator + runtime).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max requests fused into one PJRT execution.
    pub batch_size: usize,
    /// How long the batcher waits to fill a batch.
    pub batch_timeout_ms: u64,
    /// Worker threads executing batches.
    pub workers: usize,
    /// HLO artifact path.
    pub artifact: String,
    /// Bounded queue capacity (backpressure beyond this).
    pub queue_capacity: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batch_size: 8,
            batch_timeout_ms: 2,
            workers: 1,
            artifact: "artifacts/topvit_fwd.hlo.txt".into(),
            queue_capacity: 1024,
        }
    }
}

impl ServerConfig {
    pub fn from_config(c: &Config) -> Self {
        let d = ServerConfig::default();
        ServerConfig {
            batch_size: c.get_usize("server.batch_size", d.batch_size),
            batch_timeout_ms: c.get_usize("server.batch_timeout_ms", d.batch_timeout_ms as usize)
                as u64,
            workers: c.get_usize("server.workers", d.workers),
            artifact: c.get_or("model.artifact", &d.artifact).to_string(),
            queue_capacity: c.get_usize("server.queue_capacity", d.queue_capacity),
        }
    }
}

/// Typed integrator configuration (`[integrator]` section): everything
/// the `TreeFieldIntegrator` builder needs, parsed fallibly into a
/// [`CrossPolicy`].
#[derive(Debug, Clone)]
pub struct IntegratorConfig {
    /// IntegratorTree leaf threshold (`t ≥ 2`).
    pub leaf_threshold: usize,
    /// Dense-multiply cutoff `a·b`.
    pub dense_cutoff: usize,
    /// Chebyshev probe tolerance.
    pub cheb_tol: f64,
    /// Maximum Chebyshev rank.
    pub cheb_max_rank: usize,
    /// Maximum lattice points for the Hankel path.
    pub lattice_max_points: usize,
    /// Optional forced strategy name (`dense`, `separable`, `lattice`,
    /// `rational-sum`, `cauchy`, `vandermonde`, `chebyshev`).
    pub force: Option<String>,
    /// Worker threads for the parallel integrate/prepare/batch paths:
    /// `0` = auto (`FTFI_THREADS` if set, else all cores), `1` = serial.
    /// Outputs are bit-identical for every setting.
    pub threads: usize,
    /// Compute tier name (`"f64"` — the default, bit-identical path —
    /// or `"f32"`, the opt-in serving tier: f32 products, f64
    /// accumulation; tree backend only).
    pub precision: String,
}

impl Default for IntegratorConfig {
    fn default() -> Self {
        let p = CrossPolicy::default();
        IntegratorConfig {
            leaf_threshold: 32,
            dense_cutoff: p.dense_cutoff,
            cheb_tol: p.cheb_tol,
            cheb_max_rank: p.cheb_max_rank,
            lattice_max_points: p.lattice_max_points,
            force: None,
            threads: 0,
            precision: "f64".into(),
        }
    }
}

/// Parse a strategy name (as written in config files / CLI flags).
pub fn parse_strategy(name: &str) -> Result<Strategy, FtfiError> {
    match name.to_ascii_lowercase().as_str() {
        "dense" => Ok(Strategy::Dense),
        "separable" => Ok(Strategy::Separable),
        "lattice" => Ok(Strategy::Lattice),
        "rational-sum" | "rational" => Ok(Strategy::RationalSum),
        "cauchy" => Ok(Strategy::Cauchy),
        "vandermonde" => Ok(Strategy::Vandermonde),
        "chebyshev" | "cheb" => Ok(Strategy::Chebyshev),
        other => Err(FtfiError::InvalidInput(format!(
            "unknown strategy {other:?} (dense|separable|lattice|rational-sum|cauchy|\
             vandermonde|chebyshev)"
        ))),
    }
}

impl IntegratorConfig {
    pub fn from_config(c: &Config) -> Self {
        let d = IntegratorConfig::default();
        IntegratorConfig {
            leaf_threshold: c.get_usize("integrator.leaf_threshold", d.leaf_threshold),
            dense_cutoff: c.get_usize("integrator.dense_cutoff", d.dense_cutoff),
            cheb_tol: c.get_f64("integrator.cheb_tol", d.cheb_tol),
            cheb_max_rank: c.get_usize("integrator.cheb_max_rank", d.cheb_max_rank),
            lattice_max_points: c
                .get_usize("integrator.lattice_max_points", d.lattice_max_points),
            force: c.get("integrator.force").map(|s| s.to_string()),
            threads: c.get_usize("integrator.threads", d.threads),
            precision: c.get_or("integrator.precision", &d.precision).to_string(),
        }
    }

    /// Parse the precision-tier name; fails on an unknown tier instead
    /// of silently falling back to f64.
    pub fn to_precision(&self) -> Result<Precision, FtfiError> {
        Precision::parse(&self.precision).ok_or_else(|| {
            FtfiError::InvalidInput(format!(
                "unknown precision {:?} (f64|f32)",
                self.precision
            ))
        })
    }

    /// Materialise the [`CrossPolicy`]; fails on an unknown forced
    /// strategy name instead of silently ignoring it.
    pub fn to_policy(&self) -> Result<CrossPolicy, FtfiError> {
        let force = match &self.force {
            Some(name) => Some(parse_strategy(name)?),
            None => None,
        };
        let policy = CrossPolicy {
            dense_cutoff: self.dense_cutoff,
            lattice_max_points: self.lattice_max_points,
            cheb_tol: self.cheb_tol,
            cheb_max_rank: self.cheb_max_rank,
            force,
            ..CrossPolicy::default()
        };
        policy.validate()?;
        Ok(policy)
    }
}

/// Typed tree-ensemble configuration (`[ensemble]` section): the knobs
/// of the [`crate::ftfi::EnsembleFieldIntegrator`] builder. `trees = 0`
/// (the default) means "disabled — use the single-MST route".
#[derive(Debug, Clone)]
pub struct EnsembleConfig {
    /// Ensemble size `m` (`0` = single-MST route, no ensemble).
    pub trees: usize,
    /// Sampling seed — fixed `(seed, trees)` reproduces bit-identically.
    pub seed: u64,
    /// Embedding family name (`frt` or `bartal`).
    pub method: String,
}

impl Default for EnsembleConfig {
    fn default() -> Self {
        EnsembleConfig { trees: 0, seed: 0, method: "frt".into() }
    }
}

impl EnsembleConfig {
    pub fn from_config(c: &Config) -> Self {
        let d = EnsembleConfig::default();
        EnsembleConfig {
            trees: c.get_usize("ensemble.trees", d.trees),
            seed: c.get_usize("ensemble.seed", d.seed as usize) as u64,
            method: c.get_or("ensemble.method", &d.method).to_string(),
        }
    }

    /// Whether the ensemble route is enabled at all.
    pub fn enabled(&self) -> bool {
        self.trees > 0
    }

    /// Parse the method name; fails on an unknown family instead of
    /// silently falling back.
    pub fn to_method(&self) -> Result<EnsembleMethod, FtfiError> {
        EnsembleMethod::parse(&self.method)
    }
}

/// Typed streaming-serving configuration (`[streaming]` section): the
/// knobs of the per-session delta-update path
/// ([`crate::coordinator::StreamingFieldExecutor`]).
#[derive(Debug, Clone)]
pub struct StreamingConfig {
    /// Drift policy: a session performs a full bit-exact re-integration
    /// every this many updates (`0` = delta-only, drift unbounded).
    pub refresh_every: usize,
    /// Session slots per streaming executor.
    pub max_sessions: usize,
    /// In-flight updates a single session may hold before new ones are
    /// rejected with `SessionBusy` (admission control).
    pub max_pending: usize,
    /// Queue age (milliseconds) past which a request is shed instead of
    /// served (`0` = never shed).
    pub shed_after_ms: u64,
}

impl Default for StreamingConfig {
    fn default() -> Self {
        StreamingConfig { refresh_every: 64, max_sessions: 16, max_pending: 32, shed_after_ms: 0 }
    }
}

impl StreamingConfig {
    pub fn from_config(c: &Config) -> Self {
        let d = StreamingConfig::default();
        StreamingConfig {
            refresh_every: c.get_usize("streaming.refresh_every", d.refresh_every),
            max_sessions: c.get_usize("streaming.max_sessions", d.max_sessions),
            max_pending: c.get_usize("streaming.max_pending", d.max_pending),
            shed_after_ms: c.get_usize("streaming.shed_after_ms", d.shed_after_ms as usize)
                as u64,
        }
    }
}

/// Typed plan-cache configuration (`[cache]` section): the knobs of the
/// multi-graph prepared-plan LRU
/// ([`crate::coordinator::PlanCache`]) and of delta fusion in the
/// streaming executor.
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// Prepared graphs the LRU may hold (`OpenGraph`-resolved entries;
    /// the server's default graph is pinned and does not count).
    pub max_graphs: usize,
    /// Estimated-byte budget for the cache (`0` = unbounded): entries
    /// are evicted LRU-first until the estimate fits.
    pub max_bytes_mb: usize,
    /// Fuse all of one session's `Update`s landing in a batch window
    /// into a single delta pass (bit-identical to serving them one by
    /// one; see DESIGN.md "Multi-graph cache & update fusion").
    pub fuse_updates: bool,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig { max_graphs: 8, max_bytes_mb: 0, fuse_updates: true }
    }
}

impl CacheConfig {
    pub fn from_config(c: &Config) -> Self {
        let d = CacheConfig::default();
        CacheConfig {
            max_graphs: c.get_usize("cache.max_graphs", d.max_graphs),
            max_bytes_mb: c.get_usize("cache.max_bytes_mb", d.max_bytes_mb),
            fuse_updates: c.get_bool("cache.fuse_updates", d.fuse_updates),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let c = Config::parse(
            "# top\nglobal = 1\n[server]\nbatch_size = 16\nbatch_timeout_ms = 7\n[model]\nartifact = \"a/b.hlo.txt\"\nflag = true\n",
        )
        .unwrap();
        assert_eq!(c.get("global"), Some("1"));
        assert_eq!(c.get_usize("server.batch_size", 0), 16);
        assert_eq!(c.get("model.artifact"), Some("a/b.hlo.txt"));
        assert!(c.get_bool("model.flag", false));
        assert_eq!(c.get_f64("missing", 2.5), 2.5);
    }

    #[test]
    fn parse_errors() {
        assert!(Config::parse("[unterminated\n").is_err());
        assert!(Config::parse("novalue\n").is_err());
    }

    #[test]
    fn server_config_from_file_text() {
        let c = Config::parse("[server]\nbatch_size = 4\nworkers = 2\n").unwrap();
        let s = ServerConfig::from_config(&c);
        assert_eq!(s.batch_size, 4);
        assert_eq!(s.workers, 2);
        assert_eq!(s.batch_timeout_ms, 2); // default
    }

    #[test]
    fn cli_override() {
        let mut c = Config::parse("[server]\nbatch_size = 4\n").unwrap();
        c.set("server.batch_size", "32");
        assert_eq!(ServerConfig::from_config(&c).batch_size, 32);
    }

    #[test]
    fn integrator_config_roundtrip() {
        let c = Config::parse(
            "[integrator]\nleaf_threshold = 16\ndense_cutoff = 1024\nforce = chebyshev\n\
             threads = 3\n",
        )
        .unwrap();
        let ic = IntegratorConfig::from_config(&c);
        assert_eq!(ic.leaf_threshold, 16);
        assert_eq!(ic.dense_cutoff, 1024);
        assert_eq!(ic.threads, 3);
        let policy = ic.to_policy().unwrap();
        assert_eq!(policy.force, Some(Strategy::Chebyshev));
        assert_eq!(policy.dense_cutoff, 1024);
        // `threads` defaults to 0 = auto when the key is absent.
        assert_eq!(IntegratorConfig::default().threads, 0);
    }

    #[test]
    fn ensemble_config_roundtrip() {
        let c = Config::parse("[ensemble]\ntrees = 8\nseed = 17\nmethod = bartal\n").unwrap();
        let ec = EnsembleConfig::from_config(&c);
        assert!(ec.enabled());
        assert_eq!(ec.trees, 8);
        assert_eq!(ec.seed, 17);
        assert_eq!(ec.to_method().unwrap(), EnsembleMethod::Bartal);
        // Absent section → disabled, frt default.
        let d = EnsembleConfig::from_config(&Config::default());
        assert!(!d.enabled());
        assert_eq!(d.to_method().unwrap(), EnsembleMethod::Frt);
        // Unknown family is a typed error.
        let bad = EnsembleConfig { method: "steiner".into(), ..Default::default() };
        assert!(matches!(bad.to_method(), Err(FtfiError::InvalidInput(_))));
    }

    #[test]
    fn streaming_config_roundtrip() {
        let c = Config::parse(
            "[streaming]\nrefresh_every = 8\nmax_sessions = 3\nmax_pending = 5\n\
             shed_after_ms = 40\n",
        )
        .unwrap();
        let sc = StreamingConfig::from_config(&c);
        assert_eq!(sc.refresh_every, 8);
        assert_eq!(sc.max_sessions, 3);
        assert_eq!(sc.max_pending, 5);
        assert_eq!(sc.shed_after_ms, 40);
        // Absent section → defaults.
        let d = StreamingConfig::from_config(&Config::default());
        assert_eq!(d.refresh_every, 64);
        assert_eq!(d.max_sessions, 16);
        assert_eq!(d.max_pending, 32);
        assert_eq!(d.shed_after_ms, 0);
        // refresh_every = 0 is a legal "never refresh" setting.
        let z = Config::parse("[streaming]\nrefresh_every = 0\n").unwrap();
        assert_eq!(StreamingConfig::from_config(&z).refresh_every, 0);
    }

    #[test]
    fn cache_config_roundtrip() {
        let c = Config::parse("[cache]\nmax_graphs = 3\nmax_bytes_mb = 64\nfuse_updates = off\n")
            .unwrap();
        let cc = CacheConfig::from_config(&c);
        assert_eq!(cc.max_graphs, 3);
        assert_eq!(cc.max_bytes_mb, 64);
        assert!(!cc.fuse_updates);
        // Absent section → defaults (fusion on, unbounded bytes).
        let d = CacheConfig::from_config(&Config::default());
        assert_eq!(d.max_graphs, 8);
        assert_eq!(d.max_bytes_mb, 0);
        assert!(d.fuse_updates);
        // `on` spelling binds too (the CLI passes flag values through).
        let on = Config::parse("[cache]\nfuse_updates = on\n").unwrap();
        assert!(CacheConfig::from_config(&on).fuse_updates);
    }

    #[test]
    fn precision_key_roundtrip() {
        // Absent key → the f64 default tier.
        let d = IntegratorConfig::from_config(&Config::default());
        assert_eq!(d.precision, "f64");
        assert_eq!(d.to_precision().unwrap(), Precision::F64);
        let c = Config::parse("[integrator]\nprecision = \"f32\"\n").unwrap();
        let ic = IntegratorConfig::from_config(&c);
        assert_eq!(ic.to_precision().unwrap(), Precision::F32);
        // Unknown tier is a typed error, not a silent fallback.
        let bad = IntegratorConfig { precision: "f16".into(), ..Default::default() };
        assert!(matches!(bad.to_precision(), Err(FtfiError::InvalidInput(_))));
    }

    #[test]
    fn unknown_strategy_is_a_typed_error() {
        let ic = IntegratorConfig { force: Some("warp-drive".into()), ..Default::default() };
        assert!(matches!(ic.to_policy(), Err(FtfiError::InvalidInput(_))));
        assert!(parse_strategy("rational-sum").is_ok());
        assert!(parse_strategy("Dense").is_ok());
    }
}
