//! Configuration system: a small INI/TOML-subset parser (offline — no
//! serde/toml crates) plus typed config structs for the serving
//! coordinator and the experiment drivers. Files look like:
//!
//! ```text
//! # comment
//! [server]
//! batch_size = 8
//! batch_timeout_ms = 5
//!
//! [model]
//! artifact = "artifacts/topvit_b8.hlo.txt"
//! ```

use std::collections::HashMap;

/// Parsed config: `section.key -> value` strings.
#[derive(Debug, Default, Clone)]
pub struct Config {
    values: HashMap<String, String>,
}

impl Config {
    /// Parse from text. Later keys override earlier ones.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut values = HashMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(stripped) = line.strip_prefix('[') {
                let name = stripped
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = v.trim().trim_matches('"').to_string();
            values.insert(key, val);
        }
        Ok(Config { values })
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Config::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.get(key)
            .map(|v| matches!(v, "true" | "1" | "yes" | "on"))
            .unwrap_or(default)
    }

    /// Override a value (CLI flags do this on top of file configs).
    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }
}

/// Typed serving configuration (coordinator + runtime).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max requests fused into one PJRT execution.
    pub batch_size: usize,
    /// How long the batcher waits to fill a batch.
    pub batch_timeout_ms: u64,
    /// Worker threads executing batches.
    pub workers: usize,
    /// HLO artifact path.
    pub artifact: String,
    /// Bounded queue capacity (backpressure beyond this).
    pub queue_capacity: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batch_size: 8,
            batch_timeout_ms: 2,
            workers: 1,
            artifact: "artifacts/topvit_fwd.hlo.txt".into(),
            queue_capacity: 1024,
        }
    }
}

impl ServerConfig {
    pub fn from_config(c: &Config) -> Self {
        let d = ServerConfig::default();
        ServerConfig {
            batch_size: c.get_usize("server.batch_size", d.batch_size),
            batch_timeout_ms: c.get_usize("server.batch_timeout_ms", d.batch_timeout_ms as usize)
                as u64,
            workers: c.get_usize("server.workers", d.workers),
            artifact: c.get_or("model.artifact", &d.artifact).to_string(),
            queue_capacity: c.get_usize("server.queue_capacity", d.queue_capacity),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let c = Config::parse(
            "# top\nglobal = 1\n[server]\nbatch_size = 16\nbatch_timeout_ms = 7\n[model]\nartifact = \"a/b.hlo.txt\"\nflag = true\n",
        )
        .unwrap();
        assert_eq!(c.get("global"), Some("1"));
        assert_eq!(c.get_usize("server.batch_size", 0), 16);
        assert_eq!(c.get("model.artifact"), Some("a/b.hlo.txt"));
        assert!(c.get_bool("model.flag", false));
        assert_eq!(c.get_f64("missing", 2.5), 2.5);
    }

    #[test]
    fn parse_errors() {
        assert!(Config::parse("[unterminated\n").is_err());
        assert!(Config::parse("novalue\n").is_err());
    }

    #[test]
    fn server_config_from_file_text() {
        let c = Config::parse("[server]\nbatch_size = 4\nworkers = 2\n").unwrap();
        let s = ServerConfig::from_config(&c);
        assert_eq!(s.batch_size, 4);
        assert_eq!(s.workers, 2);
        assert_eq!(s.batch_timeout_ms, 2); // default
    }

    #[test]
    fn cli_override() {
        let mut c = Config::parse("[server]\nbatch_size = 4\n").unwrap();
        c.set("server.batch_size", "32");
        assert_eq!(ServerConfig::from_config(&c).batch_size, 32);
    }
}
