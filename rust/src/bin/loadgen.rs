//! `loadgen` — open-loop serving load generator for the streaming
//! stack, over the real TCP wire ([`ftfi::coordinator::TcpFront`]).
//!
//! Seeded Poisson arrivals drive one typed-wire connection per client;
//! each client multiplexes a slice of `--sessions` sessions, binding
//! every session to graph `session % --graphs` (graph 0 is the server
//! default, the rest are opened through `OpenGraph` and resolved by the
//! prepared-plan cache). Traffic is bursty per-session update *trains*:
//! a pipelined run of sparse updates for one session written
//! back-to-back — the shape the server's delta fusion collapses into a
//! single pass — interleaved with leases, re-sets and edge replans
//! through the [`ftfi::coordinator::retry_with_backoff`] helper,
//! re-admitting (re-open + re-set) after eviction and re-syncing after
//! lost responses. With `--faults chaos` a seeded [`FaultPlan`]
//! corrupts frames, drops and duplicates responses, injects latency,
//! panics workers and disconnects clients mid-stream.
//!
//! The run writes `BENCH_serving.json` (override with `--out`): client
//! latency percentiles (p50/p95/p99/p999 ms), shed/evict/protocol-error
//! /retry counters, plan-cache hit/miss/eviction + fusion counters, and
//! a loss ledger reconciled against the injected fault counters —
//! `lost_unexplained` must be 0, faults or no faults.
//!
//! ```text
//! loadgen --clients 4 --sessions 2000 --graphs 8 --requests 5200 \
//!         --cache-graphs 8 --rate 300 --seed 42
//! ```

use ftfi::cli::Args;
use ftfi::config::CacheConfig;
use ftfi::coordinator::protocol::{self, StreamRequest, StreamResponse};
use ftfi::coordinator::{
    retry_with_backoff, BackoffPolicy, BatchExecutor, BatcherConfig, FaultPlan, Faults,
    FaultyExecutor, InferenceServer, MetricsRegistry, RejectReason, RetryStep,
    StreamingFieldExecutor, TcpFront,
};
use ftfi::ftfi::TreeFieldIntegrator;
use ftfi::graph::generators;
use ftfi::ml::rng::Pcg;
use ftfi::FDist;
use std::io::BufReader;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Per-client outcome counters, merged across clients at the end.
#[derive(Default, Clone, Copy)]
struct Stats {
    attempts: u64,
    ok: u64,
    rejected: u64,
    protocol_errors: u64,
    errors: u64,
    lost: u64,
    strays: u64,
    gave_up: u64,
    retries: u64,
}

impl Stats {
    fn merge(&mut self, o: &Stats) {
        self.attempts += o.attempts;
        self.ok += o.ok;
        self.rejected += o.rejected;
        self.protocol_errors += o.protocol_errors;
        self.errors += o.errors;
        self.lost += o.lost;
        self.strays += o.strays;
        self.gave_up += o.gave_up;
        self.retries += o.retries;
    }
}

/// One typed-wire connection with req-id matching. Responses that do
/// not carry the awaited id (duplicates, strays from id-corrupted
/// frames) are counted and skipped; a read timeout or torn stream
/// returns `None` so the caller can count the loss and re-sync.
struct Client {
    addr: std::net::SocketAddr,
    conn: TcpStream,
    rd: BufReader<TcpStream>,
    next_id: u64,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> std::io::Result<Client> {
        let conn = TcpStream::connect(addr)?;
        let _ = conn.set_nodelay(true);
        conn.set_read_timeout(Some(Duration::from_millis(500)))?;
        let rd = BufReader::new(conn.try_clone()?);
        Ok(Client { addr, conn, rd, next_id: 0 })
    }

    fn reconnect(&mut self) -> bool {
        match Client::connect(self.addr) {
            Ok(mut fresh) => {
                fresh.next_id = self.next_id;
                *self = fresh;
                true
            }
            Err(_) => false,
        }
    }

    fn call(&mut self, req: &StreamRequest, strays: &mut u64) -> Option<StreamResponse> {
        let id = self.next_id;
        self.next_id += 1;
        let payload = protocol::encode_request(req, id);
        if protocol::write_frame(&mut self.conn, &payload).is_err() {
            return None;
        }
        loop {
            match protocol::read_frame(&mut self.rd) {
                Ok(Some(frame)) => match protocol::decode_response(&frame) {
                    Ok((got, resp)) if got == id => return Some(resp),
                    Ok(_) | Err(_) => *strays += 1,
                },
                Ok(None) | Err(_) => return None,
            }
        }
    }

    /// Pipeline a train: write every frame back-to-back, then collect
    /// responses by id (out-of-order tolerated) until all arrive or the
    /// read times out. `Err(())` means the write itself failed — no
    /// frame reached the server, so nothing was *lost*, the caller
    /// should reconnect and replay. Slots still `None` after a timeout
    /// are genuine losses.
    fn call_train(
        &mut self,
        reqs: &[StreamRequest],
        strays: &mut u64,
    ) -> Result<Vec<Option<StreamResponse>>, ()> {
        let ids: Vec<u64> = reqs
            .iter()
            .map(|_| {
                let id = self.next_id;
                self.next_id += 1;
                id
            })
            .collect();
        for (req, &id) in reqs.iter().zip(&ids) {
            let payload = protocol::encode_request(req, id);
            if protocol::write_frame(&mut self.conn, &payload).is_err() {
                return Err(());
            }
        }
        let mut out: Vec<Option<StreamResponse>> = vec![None; reqs.len()];
        let mut got = 0;
        while got < reqs.len() {
            match protocol::read_frame(&mut self.rd) {
                Ok(Some(frame)) => match protocol::decode_response(&frame) {
                    Ok((rid, resp)) => match ids.iter().position(|&i| i == rid) {
                        Some(pos) if out[pos].is_none() => {
                            out[pos] = Some(resp);
                            got += 1;
                        }
                        _ => *strays += 1,
                    },
                    Err(_) => *strays += 1,
                },
                Ok(None) | Err(_) => break,
            }
        }
        Ok(out)
    }
}

fn set_request(session: u32, n: usize, rng: &mut Pcg) -> StreamRequest {
    StreamRequest::Set {
        session,
        rows: n as u32,
        channels: 1,
        values: (0..n).map(|_| rng.normal() as f32).collect(),
    }
}

fn open_request(session: u32, n: usize, edges: &[(u32, u32, f64)]) -> StreamRequest {
    StreamRequest::OpenGraph { session, n: n as u32, edges: edges.to_vec() }
}

/// Re-admit a session after eviction or a lost-response re-sync: bind
/// its graph again (sessions off the default graph must re-open, or the
/// bare `Set` would silently rebind them to graph 0), then re-seed the
/// field. Bookkeeping traffic — not counted against the request budget.
fn readmit(
    client: &mut Client,
    session: u32,
    n: usize,
    gi: usize,
    graphs: &[Arc<Vec<(u32, u32, f64)>>],
    rng: &mut Pcg,
    strays: &mut u64,
) {
    if gi > 0 {
        let _ = client.call(&open_request(session, n, &graphs[gi]), strays);
    }
    let _ = client.call(&set_request(session, n, rng), strays);
}

/// Drive one request to completion with backoff retries, eviction
/// re-admission and lost-response re-sync; counts the outcome and
/// records the latency on success.
#[allow(clippy::too_many_arguments)]
fn execute_one(
    policy: &BackoffPolicy,
    client: &mut Client,
    req: &StreamRequest,
    session: u32,
    n: usize,
    gi: usize,
    graphs: &[Arc<Vec<(u32, u32, f64)>>],
    rng: &mut Pcg,
    stats: &mut Stats,
    lat: &mut Vec<f64>,
    retry_seed: u64,
) -> bool {
    let t0 = Instant::now();
    let (outcome, retries) = retry_with_backoff(policy, retry_seed, |_| {
        stats.attempts += 1;
        match client.call(req, &mut stats.strays) {
            Some(StreamResponse::Output { .. }) | Some(StreamResponse::Closed { .. }) => {
                RetryStep::Done(())
            }
            Some(StreamResponse::Rejected { reason: RejectReason::Evicted, .. }) => {
                stats.rejected += 1;
                readmit(client, session, n, gi, graphs, rng, &mut stats.strays);
                RetryStep::Retry(())
            }
            Some(StreamResponse::Rejected { .. }) => {
                stats.rejected += 1;
                RetryStep::Retry(())
            }
            Some(StreamResponse::Error { message }) => {
                if message.starts_with(protocol::ERR_PROTOCOL_PREFIX) {
                    stats.protocol_errors += 1;
                } else {
                    stats.errors += 1;
                }
                RetryStep::Fail(())
            }
            None => {
                // Timeout or torn stream: the response is lost.
                // Re-sync framing with a fresh connection + re-admit.
                stats.lost += 1;
                if client.reconnect() {
                    readmit(client, session, n, gi, graphs, rng, &mut stats.strays);
                    RetryStep::Retry(())
                } else {
                    RetryStep::Fail(())
                }
            }
        }
    });
    stats.retries += u64::from(retries);
    match outcome {
        Ok(()) => {
            stats.ok += 1;
            lat.push(t0.elapsed().as_secs_f64());
            true
        }
        Err(()) => {
            stats.gave_up += 1;
            false
        }
    }
}

/// Drive one client thread: round-robin over its session slice, one
/// bursty update train per visit (first visit opens the session's graph
/// and seeds its field), with leases / re-sets / replans sprinkled in.
/// Returns the counters plus the end-to-end latency (seconds) of each
/// success (one sample per train, one per single request).
#[allow(clippy::too_many_arguments)]
fn drive_client(
    addr: std::net::SocketAddr,
    client_idx: usize,
    clients: usize,
    sessions: usize,
    n: usize,
    per_client: usize,
    rate: f64,
    seed: u64,
    graphs: Arc<Vec<Arc<Vec<(u32, u32, f64)>>>>,
    faults: Option<Arc<Faults>>,
) -> (Stats, Vec<f64>) {
    let mut stats = Stats::default();
    let mut lat = Vec::with_capacity(per_client);
    let owned: Vec<u32> = (client_idx as u32..sessions as u32).step_by(clients).collect();
    if owned.is_empty() {
        return (stats, lat);
    }
    let mut admitted = vec![false; owned.len()];
    let mut rng = Pcg::new(seed, 0x10AD ^ client_idx as u64);
    let mut client = match Client::connect(addr) {
        Ok(c) => c,
        Err(_) => {
            stats.gave_up = per_client as u64;
            return (stats, lat);
        }
    };
    let policy = BackoffPolicy::default();
    let mut next_arrival = Instant::now();
    let mut issued = 0usize;
    let mut train = 0usize;
    while issued < per_client {
        let si = train % owned.len();
        train += 1;
        let session = owned[si];
        let gi = session as usize % graphs.len();
        // Open-loop pacing: one exponential inter-arrival per train,
        // scaled so the *per-request* rate stays ~`rate`; the train
        // itself is written back-to-back (that is the burst).
        next_arrival += Duration::from_secs_f64(rng.exponential(rate / 8.0));
        let now = Instant::now();
        if next_arrival > now {
            std::thread::sleep(next_arrival - now);
        }
        // Fault: disconnect mid-stream, then recover by reconnecting
        // and re-admitting the session.
        if let Some(f) = faults.as_ref() {
            if f.take_disconnect() && client.reconnect() {
                readmit(&mut client, session, n, gi, &graphs, &mut rng, &mut stats.strays);
            }
        }
        // First visit: bind the graph (OpenGraph for non-default
        // graphs), then seed the field. Both count against the budget.
        if !admitted[si] {
            if gi > 0 {
                let req = open_request(session, n, &graphs[gi]);
                execute_one(
                    &policy, &mut client, &req, session, n, gi, &graphs, &mut rng, &mut stats,
                    &mut lat, seed ^ issued as u64,
                );
                issued += 1;
                if issued >= per_client {
                    break;
                }
            }
            let req = set_request(session, n, &mut rng);
            execute_one(
                &policy, &mut client, &req, session, n, gi, &graphs, &mut rng, &mut stats,
                &mut lat, seed ^ issued as u64,
            );
            issued += 1;
            admitted[si] = true;
            continue;
        }
        // Occasional singles keep the non-update paths hot.
        if rng.below(20) < 3 {
            let req = match rng.below(4) {
                0 => set_request(session, n, &mut rng),
                1 => {
                    let edges = &graphs[gi];
                    let (u, v, w) = edges[rng.below(edges.len())];
                    let scale = if rng.bool(0.5) { 1.25 } else { 0.8 };
                    StreamRequest::ReplanEdge { session, u, v, w: w * scale }
                }
                _ => StreamRequest::Lease { session },
            };
            execute_one(
                &policy, &mut client, &req, session, n, gi, &graphs, &mut rng, &mut stats,
                &mut lat, seed ^ issued as u64,
            );
            issued += 1;
            continue;
        }
        // The bursty per-session update train: a pipelined run of
        // sparse updates for this one session — the server fuses all of
        // them that land in one batch window into a single delta pass.
        let burst = 8.min(per_client - issued).max(1);
        let reqs: Vec<StreamRequest> = (0..burst)
            .map(|_| {
                let k = 4.min(n);
                let start = rng.below(n);
                StreamRequest::Update {
                    session,
                    rows: (0..k).map(|j| ((start + j) % n) as u32).collect(),
                    channels: 1,
                    values: (0..k).map(|_| rng.normal() as f32).collect(),
                }
            })
            .collect();
        let t0 = Instant::now();
        stats.attempts += burst as u64;
        let resps = match client.call_train(&reqs, &mut stats.strays) {
            Ok(r) => r,
            Err(()) => {
                // The write failed before anything reached the server:
                // nothing was lost — reconnect and replay every member
                // through the retrying single path.
                if client.reconnect() {
                    readmit(&mut client, session, n, gi, &graphs, &mut rng, &mut stats.strays);
                }
                for req in &reqs {
                    issued += 1;
                    execute_one(
                        &policy, &mut client, req, session, n, gi, &graphs, &mut rng, &mut stats,
                        &mut lat, seed ^ issued as u64,
                    );
                }
                continue;
            }
        };
        let train_ok = resps
            .iter()
            .filter(|r| matches!(r, Some(StreamResponse::Output { .. })))
            .count();
        if train_ok > 0 {
            // One latency sample for the whole pipelined round trip.
            lat.push(t0.elapsed().as_secs_f64());
        }
        let mut resynced = false;
        for (req, resp) in reqs.iter().zip(resps) {
            issued += 1;
            match resp {
                Some(StreamResponse::Output { .. }) | Some(StreamResponse::Closed { .. }) => {
                    stats.ok += 1;
                }
                Some(StreamResponse::Rejected { reason, .. }) => {
                    stats.rejected += 1;
                    if matches!(reason, RejectReason::Evicted) {
                        readmit(&mut client, session, n, gi, &graphs, &mut rng, &mut stats.strays);
                    }
                    execute_one(
                        &policy, &mut client, req, session, n, gi, &graphs, &mut rng, &mut stats,
                        &mut lat, seed ^ issued as u64,
                    );
                }
                Some(StreamResponse::Error { message }) => {
                    if message.starts_with(protocol::ERR_PROTOCOL_PREFIX) {
                        stats.protocol_errors += 1;
                    } else {
                        stats.errors += 1;
                    }
                    stats.gave_up += 1;
                }
                None => {
                    // A response never arrived for this member: count
                    // the loss once, re-sync once per train, and replay
                    // through the retrying single path.
                    stats.lost += 1;
                    if !resynced {
                        resynced = true;
                        if client.reconnect() {
                            readmit(
                                &mut client, session, n, gi, &graphs, &mut rng, &mut stats.strays,
                            );
                        }
                    }
                    execute_one(
                        &policy, &mut client, req, session, n, gi, &graphs, &mut rng, &mut stats,
                        &mut lat, seed ^ issued as u64,
                    );
                }
            }
        }
    }
    (stats, lat)
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn run(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let n = args.get_usize("n", 200).max(2);
    let clients = args.get_usize("clients", 4).max(1);
    let per_client = args.get_usize("requests", 150).max(1);
    let seed = args.get_usize("seed", 42) as u64;
    let rate = args.get_f64("rate", 400.0).max(1.0);
    let workers = args.get_usize("workers", 2).max(1);
    let fault_mode = args.get_str("faults", "none");
    let out = args.get_str("out", "BENCH_serving.json");
    let sessions = args.get_usize("sessions", clients).max(1);
    let n_graphs = args.get_usize("graphs", 1).max(1);
    let cache_graphs = args.get_usize("cache-graphs", 8).max(1);
    let fuse_updates = match args.get_str("fuse-updates", "on") {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => return Err(format!("unknown --fuse-updates {other:?} (on|off)").into()),
    };
    let max_sessions = args.get_usize("max-sessions", sessions).max(1);
    let shed_after_ms = args.get_usize("shed-after-ms", 50) as u64;

    let plan = match fault_mode {
        "none" => FaultPlan::off(),
        "chaos" => FaultPlan::chaos(seed),
        other => return Err(format!("unknown --faults {other:?} (none|chaos)").into()),
    };
    let faults = Faults::new(&plan);

    let mut rng = Pcg::seed(seed);
    let tree = generators::random_tree(n, 0.2, 1.0, &mut rng);
    // Graph 0 is the server default; the rest are opened through
    // `OpenGraph` and live in the prepared-plan cache. All share `n` so
    // sessions can migrate between them without re-shaping.
    let graphs: Arc<Vec<Arc<Vec<(u32, u32, f64)>>>> = Arc::new(
        std::iter::once(Arc::new(tree.edges().to_vec()))
            .chain((1..n_graphs).map(|gi| {
                let mut grng = Pcg::seed(seed ^ (0x06A0 + gi as u64));
                Arc::new(generators::random_tree(n, 0.2, 1.0, &mut grng).edges().to_vec())
            }))
            .collect(),
    );
    let f = FDist::Exponential { lambda: -0.5, scale: 1.0 };
    let tfi = TreeFieldIntegrator::builder(&tree).threads(1).build()?;
    let metrics = Arc::new(MetricsRegistry::new());
    let exec = Arc::new(
        StreamingFieldExecutor::new(tfi, &f, 1, 16, max_sessions, 8)?
            .with_cache(CacheConfig { max_graphs: cache_graphs, max_bytes_mb: 0, fuse_updates })
            .with_metrics(Arc::clone(&metrics)),
    );
    let factories: Vec<Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>> = (0..workers)
        .map(|_| {
            let exec = Arc::clone(&exec);
            let faults = faults.clone();
            Box::new(move || match faults {
                Some(f) => Box::new(FaultyExecutor::new(exec, f)) as Box<dyn BatchExecutor>,
                None => Box::new(exec) as Box<dyn BatchExecutor>,
            }) as Box<dyn FnOnce() -> Box<dyn BatchExecutor> + Send>
        })
        .collect();
    let server = Arc::new(InferenceServer::start_with_metrics(
        factories,
        BatcherConfig {
            batch_size: 8,
            batch_timeout: Duration::from_millis(2),
            shed_after: (shed_after_ms > 0).then(|| Duration::from_millis(shed_after_ms)),
        },
        256,
        Arc::clone(&metrics),
    ));
    let front = TcpFront::start(Arc::clone(&server), faults.clone(), "127.0.0.1:0")?;
    let addr = front.local_addr();
    println!(
        "loadgen: {clients} clients x {per_client} requests at ~{rate:.0} req/s each, \
         {sessions} sessions over {n_graphs} graphs (cache {cache_graphs}, fusion {}), \
         n = {n}, {workers} workers, {max_sessions} session slots, faults = {fault_mode}",
        if fuse_updates { "on" } else { "off" }
    );

    let t0 = Instant::now();
    let threads: Vec<_> = (0..clients)
        .map(|c| {
            let graphs = Arc::clone(&graphs);
            let faults = faults.clone();
            std::thread::spawn(move || {
                drive_client(addr, c, clients, sessions, n, per_client, rate, seed, graphs, faults)
            })
        })
        .collect();
    let mut stats = Stats::default();
    let mut latencies = Vec::new();
    for t in threads {
        let (s, lat) = t.join().map_err(|_| "client thread panicked")?;
        stats.merge(&s);
        latencies.extend(lat);
    }
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    front.stop();
    metrics.record_retries(stats.retries);
    let snap = metrics.snapshot();
    let injected = faults.as_ref().map(|f| f.counters()).unwrap_or_default();

    latencies.sort_by(f64::total_cmp);
    let (p50, p95, p99, p999) = (
        percentile(&latencies, 50.0) * 1e3,
        percentile(&latencies, 95.0) * 1e3,
        percentile(&latencies, 99.0) * 1e3,
        percentile(&latencies, 99.9) * 1e3,
    );
    let requested = (clients * per_client) as u64;
    // Every lost response must trace to an injected drop or to a stray
    // (a response re-keyed by an id-corrupting frame flip).
    let lost_unexplained = stats.lost.saturating_sub(injected.responses_dropped + stats.strays);
    let throughput = stats.ok as f64 / elapsed;
    let lookups = snap.cache_hits + snap.cache_misses;
    let hit_rate =
        if lookups == 0 { 1.0 } else { snap.cache_hits as f64 / lookups as f64 };

    println!(
        "done in {elapsed:.2}s: {}/{requested} ok ({:.0} req/s), p50 {p50:.2}ms \
         p95 {p95:.2}ms p99 {p99:.2}ms p99.9 {p999:.2}ms",
        stats.ok, throughput
    );
    println!(
        "client ledger: {} rejected, {} protocol errors, {} other errors, {} lost \
         ({lost_unexplained} unexplained), {} strays, {} retries, {} gave up",
        stats.rejected, stats.protocol_errors, stats.errors, stats.lost, stats.strays,
        stats.retries, stats.gave_up
    );
    println!(
        "server counters: {} shed, {} evicted, {} protocol errors, {} worker panics",
        snap.requests_shed, snap.sessions_evicted, snap.protocol_errors, snap.worker_panics
    );
    println!(
        "plan cache: {} hits / {} misses ({:.1}% hit rate), {} evictions, {} resident; \
         fusion: {} updates fused, {} delta rows saved",
        snap.cache_hits,
        snap.cache_misses,
        hit_rate * 100.0,
        snap.cache_evictions,
        snap.cache_graphs,
        snap.fused_updates,
        snap.fusion_rows_saved
    );

    let mut json = String::from("{\n  \"bench\": \"serving_soak\",\n");
    json.push_str(&format!(
        "  \"seed\": {seed}, \"clients\": {clients}, \"sessions\": {sessions}, \
         \"graphs\": {n_graphs}, \"requested\": {requested}, \"faults\": \"{fault_mode}\",\n"
    ));
    json.push_str(&format!(
        "  \"ok\": {}, \"rejected\": {}, \"protocol_errors_seen\": {}, \"errors\": {}, \
         \"gave_up\": {},\n",
        stats.ok, stats.rejected, stats.protocol_errors, stats.errors, stats.gave_up
    ));
    json.push_str(&format!(
        "  \"lost\": {}, \"strays\": {}, \"lost_unexplained\": {lost_unexplained},\n",
        stats.lost, stats.strays
    ));
    json.push_str(&format!(
        "  \"p50_ms\": {p50:.3}, \"p95_ms\": {p95:.3}, \"p99_ms\": {p99:.3}, \
         \"p999_ms\": {p999:.3},\n"
    ));
    json.push_str(&format!("  \"throughput_rps\": {throughput:.1},\n"));
    json.push_str(&format!(
        "  \"cache\": {{ \"hits\": {}, \"misses\": {}, \"hit_rate\": {hit_rate:.4}, \
         \"evictions\": {}, \"resident_graphs\": {}, \"bytes\": {}, \"fused_updates\": {}, \
         \"fusion_rows_saved\": {} }},\n",
        snap.cache_hits,
        snap.cache_misses,
        snap.cache_evictions,
        snap.cache_graphs,
        snap.cache_bytes,
        snap.fused_updates,
        snap.fusion_rows_saved
    ));
    json.push_str(&format!(
        "  \"server\": {{ \"requests\": {}, \"requests_shed\": {}, \"sessions_evicted\": {}, \
         \"protocol_errors\": {}, \"retries\": {}, \"worker_panics\": {} }},\n",
        snap.requests, snap.requests_shed, snap.sessions_evicted, snap.protocol_errors,
        snap.retries, snap.worker_panics
    ));
    json.push_str(&format!(
        "  \"injected\": {{ \"frames_corrupted\": {}, \"responses_dropped\": {}, \
         \"responses_duplicated\": {}, \"disconnects\": {}, \"delays\": {}, \
         \"panics\": {} }}\n}}\n",
        injected.frames_corrupted,
        injected.responses_dropped,
        injected.responses_duplicated,
        injected.disconnects,
        injected.delays_injected,
        injected.panics_injected
    ));
    std::fs::write(out, json)?;
    println!("wrote {out}");
    Ok(())
}
