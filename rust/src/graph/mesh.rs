//! Procedural 3-D triangle meshes — the Thingi10K substitute.
//!
//! The paper's mesh experiments (Fig. 3 right, Fig. 4, §4.2, Appendix D.3)
//! use 3-D-printed object scans. Offline we generate procedural meshes
//! with the same relevant characteristics: closed/open 2-manifold
//! surfaces, locality (bounded vertex degree), non-trivial curvature
//! (so vertex normals vary), and sizes from hundreds to tens of
//! thousands of vertices. Exact analytic vertex normals are carried as
//! ground truth for the interpolation task. An OFF-format writer/parser
//! round-trips meshes to disk for the examples.

use super::Graph;
use crate::ml::rng::Pcg;

/// A triangle mesh: positions, faces, per-vertex unit normals.
#[derive(Clone, Debug)]
pub struct Mesh {
    pub positions: Vec<[f64; 3]>,
    pub faces: Vec<[u32; 3]>,
    pub normals: Vec<[f64; 3]>,
}

fn normalize(v: [f64; 3]) -> [f64; 3] {
    let n = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt().max(1e-12);
    [v[0] / n, v[1] / n, v[2] / n]
}

impl Mesh {
    pub fn n_vertices(&self) -> usize {
        self.positions.len()
    }

    /// The mesh's edge graph with Euclidean edge lengths — the input to
    /// MST + FTFI in the interpolation pipeline.
    pub fn to_graph(&self) -> Graph {
        let mut edges = Vec::with_capacity(self.faces.len() * 3);
        for f in &self.faces {
            for (a, b) in [(f[0], f[1]), (f[1], f[2]), (f[2], f[0])] {
                let (a, b) = (a.min(b), a.max(b));
                let pa = self.positions[a as usize];
                let pb = self.positions[b as usize];
                let w = ((pa[0] - pb[0]).powi(2)
                    + (pa[1] - pb[1]).powi(2)
                    + (pa[2] - pb[2]).powi(2))
                .sqrt()
                .max(1e-9);
                edges.push((a, b, w));
            }
        }
        Graph::from_edges(self.positions.len(), &edges)
    }

    /// Recompute area-weighted vertex normals from face geometry (used to
    /// sanity-check the analytic normals of the generators).
    pub fn face_averaged_normals(&self) -> Vec<[f64; 3]> {
        let mut acc = vec![[0.0; 3]; self.positions.len()];
        for f in &self.faces {
            let [a, b, c] = [
                self.positions[f[0] as usize],
                self.positions[f[1] as usize],
                self.positions[f[2] as usize],
            ];
            let u = [b[0] - a[0], b[1] - a[1], b[2] - a[2]];
            let v = [c[0] - a[0], c[1] - a[1], c[2] - a[2]];
            let n = [
                u[1] * v[2] - u[2] * v[1],
                u[2] * v[0] - u[0] * v[2],
                u[0] * v[1] - u[1] * v[0],
            ];
            for &i in f {
                for k in 0..3 {
                    acc[i as usize][k] += n[k];
                }
            }
        }
        acc.into_iter().map(normalize).collect()
    }

    /// Serialise as OFF text.
    pub fn to_off(&self) -> String {
        let mut s = String::from("OFF\n");
        s.push_str(&format!("{} {} 0\n", self.positions.len(), self.faces.len()));
        for p in &self.positions {
            s.push_str(&format!("{} {} {}\n", p[0], p[1], p[2]));
        }
        for f in &self.faces {
            s.push_str(&format!("3 {} {} {}\n", f[0], f[1], f[2]));
        }
        s
    }

    /// Parse OFF text (triangles only). Normals are recomputed.
    pub fn from_off(text: &str) -> Result<Mesh, String> {
        let mut lines = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'));
        let header = lines.next().ok_or("empty OFF")?;
        if header != "OFF" {
            return Err(format!("bad header {header:?}"));
        }
        let counts = lines.next().ok_or("missing counts")?;
        let mut it = counts.split_whitespace();
        let nv: usize = it.next().ok_or("nv")?.parse().map_err(|e| format!("{e}"))?;
        let nf: usize = it.next().ok_or("nf")?.parse().map_err(|e| format!("{e}"))?;
        let mut positions = Vec::with_capacity(nv);
        for _ in 0..nv {
            let l = lines.next().ok_or("truncated vertices")?;
            let xs: Vec<f64> = l.split_whitespace().map(|t| t.parse().unwrap_or(0.0)).collect();
            if xs.len() < 3 {
                return Err(format!("bad vertex line {l:?}"));
            }
            positions.push([xs[0], xs[1], xs[2]]);
        }
        let mut faces = Vec::with_capacity(nf);
        for _ in 0..nf {
            let l = lines.next().ok_or("truncated faces")?;
            let xs: Vec<u32> = l.split_whitespace().map(|t| t.parse().unwrap_or(0)).collect();
            if xs.len() < 4 || xs[0] != 3 {
                return Err(format!("non-triangle face {l:?}"));
            }
            faces.push([xs[1], xs[2], xs[3]]);
        }
        let mut m = Mesh { positions, faces, normals: Vec::new() };
        m.normals = m.face_averaged_normals();
        Ok(m)
    }
}

/// UV-sphere with `rings×segs` resolution and radial distortion `bump`
/// (sinusoidal radius modulation gives non-constant curvature).
pub fn sphere_mesh(rings: usize, segs: usize, bump: f64, rng: &mut Pcg) -> Mesh {
    assert!(rings >= 3 && segs >= 3);
    let phase = rng.uniform_in(0.0, std::f64::consts::TAU);
    let mut positions = Vec::new();
    positions.push([0.0, 0.0, 1.0]);
    for r in 1..rings {
        let theta = std::f64::consts::PI * r as f64 / rings as f64;
        for s in 0..segs {
            let phi = std::f64::consts::TAU * s as f64 / segs as f64;
            let rad = 1.0 + bump * (3.0 * theta + 2.0 * phi + phase).sin();
            positions.push([
                rad * theta.sin() * phi.cos(),
                rad * theta.sin() * phi.sin(),
                rad * theta.cos(),
            ]);
        }
    }
    positions.push([0.0, 0.0, -1.0]);
    let south = (positions.len() - 1) as u32;
    let idx = |r: usize, s: usize| -> u32 { 1 + ((r - 1) * segs + (s % segs)) as u32 };
    let mut faces = Vec::new();
    for s in 0..segs {
        faces.push([0, idx(1, s), idx(1, s + 1)]);
        faces.push([south, idx(rings - 1, s + 1), idx(rings - 1, s)]);
    }
    for r in 1..rings - 1 {
        for s in 0..segs {
            let (a, b, c, d) = (idx(r, s), idx(r, s + 1), idx(r + 1, s + 1), idx(r + 1, s));
            // Winding chosen so cross products point outward.
            faces.push([a, c, b]);
            faces.push([a, d, c]);
        }
    }
    let mut m = Mesh { positions, faces, normals: Vec::new() };
    m.normals = m.face_averaged_normals();
    m
}

/// Torus mesh (major radius 1, minor `minor`), optionally noise-perturbed.
pub fn torus_mesh(rings: usize, segs: usize, minor: f64, noise: f64, rng: &mut Pcg) -> Mesh {
    assert!(rings >= 3 && segs >= 3);
    let mut positions = Vec::with_capacity(rings * segs);
    for r in 0..rings {
        let u = std::f64::consts::TAU * r as f64 / rings as f64;
        for s in 0..segs {
            let v = std::f64::consts::TAU * s as f64 / segs as f64;
            let rr = minor * (1.0 + noise * rng.normal() * 0.1);
            positions.push([
                (1.0 + rr * v.cos()) * u.cos(),
                (1.0 + rr * v.cos()) * u.sin(),
                rr * v.sin(),
            ]);
        }
    }
    let idx = |r: usize, s: usize| ((r % rings) * segs + (s % segs)) as u32;
    let mut faces = Vec::with_capacity(2 * rings * segs);
    for r in 0..rings {
        for s in 0..segs {
            let (a, b, c, d) = (idx(r, s), idx(r + 1, s), idx(r + 1, s + 1), idx(r, s + 1));
            faces.push([a, b, c]);
            faces.push([a, c, d]);
        }
    }
    let mut m = Mesh { positions, faces, normals: Vec::new() };
    m.normals = m.face_averaged_normals();
    m
}

/// Height-field terrain over a `rows×cols` grid (open surface) — smooth
/// large-scale structure plus noise, a stand-in for scanned objects.
pub fn terrain_mesh(rows: usize, cols: usize, roughness: f64, rng: &mut Pcg) -> Mesh {
    assert!(rows >= 2 && cols >= 2);
    let (p1, p2) = (rng.uniform_in(0.5, 2.0), rng.uniform_in(0.5, 2.0));
    let (q1, q2) = (rng.uniform_in(0.0, 6.0), rng.uniform_in(0.0, 6.0));
    let mut positions = Vec::with_capacity(rows * cols);
    for r in 0..rows {
        for c in 0..cols {
            let x = r as f64 / (rows - 1) as f64 * 4.0;
            let y = c as f64 / (cols - 1) as f64 * 4.0;
            let z =
                (p1 * x + q1).sin() * (p2 * y + q2).cos() + roughness * rng.normal() * 0.05;
            positions.push([x, y, z]);
        }
    }
    let idx = |r: usize, c: usize| (r * cols + c) as u32;
    let mut faces = Vec::new();
    for r in 0..rows - 1 {
        for c in 0..cols - 1 {
            faces.push([idx(r, c), idx(r, c + 1), idx(r + 1, c + 1)]);
            faces.push([idx(r, c), idx(r + 1, c + 1), idx(r + 1, c)]);
        }
    }
    let mut m = Mesh { positions, faces, normals: Vec::new() };
    m.normals = m.face_averaged_normals();
    m
}

/// The Thingi10K-substitute collection used by Fig. 3/Fig. 4: a mixture
/// of shapes at a target vertex budget.
pub fn mesh_zoo(target_vertices: usize, seed: u64) -> Vec<(String, Mesh)> {
    let mut rng = Pcg::seed(seed);
    let side = ((target_vertices as f64).sqrt() as usize).max(4);
    vec![
        ("sphere".into(), sphere_mesh(side.max(3), side.max(3), 0.15, &mut rng)),
        ("torus".into(), torus_mesh(side.max(3), side.max(3), 0.35, 0.5, &mut rng)),
        ("terrain".into(), terrain_mesh(side, side, 1.0, &mut rng)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sphere_connectivity_and_normals() {
        let mut rng = Pcg::seed(1);
        let m = sphere_mesh(8, 12, 0.0, &mut rng);
        assert_eq!(m.n_vertices(), 2 + 7 * 12);
        let g = m.to_graph();
        assert!(g.is_connected());
        // For a perfect sphere the normal equals the position direction.
        for (p, n) in m.positions.iter().zip(&m.normals) {
            let pn = normalize(*p);
            let dot: f64 = pn.iter().zip(n).map(|(a, b)| a * b).sum();
            assert!(dot > 0.97, "normal misaligned: {dot}");
        }
    }

    #[test]
    fn torus_is_closed_manifold() {
        let mut rng = Pcg::seed(2);
        let m = torus_mesh(10, 14, 0.3, 0.0, &mut rng);
        assert_eq!(m.n_vertices(), 140);
        // Euler characteristic of a torus: V - E + F = 0.
        let g = m.to_graph();
        let euler = m.n_vertices() as i64 - g.m() as i64 + m.faces.len() as i64;
        assert_eq!(euler, 0);
        for n in &m.normals {
            let len = (n[0] * n[0] + n[1] * n[1] + n[2] * n[2]).sqrt();
            assert!((len - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn terrain_open_surface() {
        let mut rng = Pcg::seed(3);
        let m = terrain_mesh(12, 9, 0.0, &mut rng);
        assert_eq!(m.n_vertices(), 108);
        // Euler characteristic of a disc: V - E + F = 1.
        let g = m.to_graph();
        let euler = m.n_vertices() as i64 - g.m() as i64 + m.faces.len() as i64;
        assert_eq!(euler, 1);
        assert!(g.is_connected());
    }

    #[test]
    fn off_roundtrip() {
        let mut rng = Pcg::seed(4);
        let m = torus_mesh(5, 6, 0.3, 0.0, &mut rng);
        let text = m.to_off();
        let back = Mesh::from_off(&text).unwrap();
        assert_eq!(back.n_vertices(), m.n_vertices());
        assert_eq!(back.faces, m.faces);
        for (a, b) in back.positions.iter().zip(&m.positions) {
            for k in 0..3 {
                assert!((a[k] - b[k]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn off_rejects_garbage() {
        assert!(Mesh::from_off("").is_err());
        assert!(Mesh::from_off("PLY\n1 0 0\n0 0 0\n").is_err());
    }

    #[test]
    fn zoo_sizes_scale() {
        let small = mesh_zoo(100, 7);
        let large = mesh_zoo(2500, 7);
        for ((_, s), (_, l)) in small.iter().zip(&large) {
            assert!(l.n_vertices() > 3 * s.n_vertices());
        }
    }
}
