//! Weighted undirected graphs in compressed-sparse-row form, plus the
//! substrates built on them (shortest paths, MST, generators, meshes,
//! point clouds, synthetic TU-style datasets).

pub mod generators;
pub mod mesh;
pub mod mst;
pub mod point_cloud;
pub mod shortest_path;
pub mod tu_dataset;
pub mod union_find;

/// An undirected weighted graph stored as CSR. Every undirected edge
/// `{u,v}` appears twice in the adjacency arrays (once per endpoint).
#[derive(Clone, Debug)]
pub struct Graph {
    n: usize,
    offsets: Vec<usize>,
    neighbors: Vec<u32>,
    weights: Vec<f64>,
    /// The unique undirected edge list (u < v) the CSR was built from.
    edges: Vec<(u32, u32, f64)>,
}

impl Graph {
    /// Build from an undirected edge list. Self-loops are dropped;
    /// duplicate edges keep the smallest weight.
    pub fn from_edges(n: usize, edges: &[(u32, u32, f64)]) -> Self {
        // Ordered map: iterating it yields edges already sorted by
        // (u, v), which both replaces the explicit sort the HashMap
        // version needed and keeps the CSR layout (and so every
        // downstream floating-point reduction) independent of hasher
        // state. Bit-identical to the old HashMap + sort construction —
        // pinned by `from_edges_matches_the_hashmap_reference` below.
        let mut dedup: std::collections::BTreeMap<(u32, u32), f64> =
            std::collections::BTreeMap::new();
        for &(u, v, w) in edges {
            assert!((u as usize) < n && (v as usize) < n, "edge ({u},{v}) out of range n={n}");
            assert!(w > 0.0, "edge weights must be positive, got {w}");
            if u == v {
                continue;
            }
            let key = (u.min(v), u.max(v));
            dedup
                .entry(key)
                .and_modify(|old| {
                    if w < *old {
                        *old = w;
                    }
                })
                .or_insert(w);
        }
        let uniq: Vec<(u32, u32, f64)> =
            dedup.into_iter().map(|((u, v), w)| (u, v, w)).collect();
        debug_assert!(uniq.windows(2).all(|p| (p[0].0, p[0].1) < (p[1].0, p[1].1)));

        let mut deg = vec![0usize; n];
        for &(u, v, _) in &uniq {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut offsets = vec![0usize; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let m2 = offsets[n];
        let mut neighbors = vec![0u32; m2];
        let mut weights = vec![0.0f64; m2];
        let mut cursor = offsets.clone();
        for &(u, v, w) in &uniq {
            neighbors[cursor[u as usize]] = v;
            weights[cursor[u as usize]] = w;
            cursor[u as usize] += 1;
            neighbors[cursor[v as usize]] = u;
            weights[cursor[v as usize]] = w;
            cursor[v as usize] += 1;
        }
        Graph { n, offsets, neighbors, weights, edges: uniq }
    }

    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of undirected edges.
    #[inline]
    pub fn m(&self) -> usize {
        self.edges.len()
    }

    /// Neighbours of `v` with edge weights.
    #[inline]
    pub fn neighbors(&self, v: usize) -> impl Iterator<Item = (u32, f64)> + '_ {
        let lo = self.offsets[v];
        let hi = self.offsets[v + 1];
        self.neighbors[lo..hi].iter().copied().zip(self.weights[lo..hi].iter().copied())
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        self.offsets[v + 1] - self.offsets[v]
    }

    /// The unique undirected edge list (u < v).
    #[inline]
    pub fn edges(&self) -> &[(u32, u32, f64)] {
        &self.edges
    }

    /// Is the graph connected? (Empty graphs count as connected.)
    pub fn is_connected(&self) -> bool {
        if self.n <= 1 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for (u, _) in self.neighbors(v) {
                if !seen[u as usize] {
                    seen[u as usize] = true;
                    count += 1;
                    stack.push(u as usize);
                }
            }
        }
        count == self.n
    }

    /// Total weight of all edges.
    pub fn total_weight(&self) -> f64 {
        self.edges.iter().map(|&(_, _, w)| w).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Graph {
        Graph::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0), (0, 2, 3.0)])
    }

    #[test]
    fn csr_layout() {
        let g = triangle();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 3);
        assert_eq!(g.degree(0), 2);
        let nbrs: Vec<_> = g.neighbors(1).collect();
        assert_eq!(nbrs.len(), 2);
        assert!(nbrs.contains(&(0, 1.0)));
        assert!(nbrs.contains(&(2, 2.0)));
    }

    #[test]
    fn dedup_keeps_min_weight() {
        let g = Graph::from_edges(2, &[(0, 1, 5.0), (1, 0, 2.0)]);
        assert_eq!(g.m(), 1);
        assert_eq!(g.edges()[0].2, 2.0);
    }

    #[test]
    fn self_loops_dropped() {
        let g = Graph::from_edges(2, &[(0, 0, 1.0), (0, 1, 1.0)]);
        assert_eq!(g.m(), 1);
    }

    #[test]
    fn connectivity() {
        assert!(triangle().is_connected());
        let g = Graph::from_edges(4, &[(0, 1, 1.0), (2, 3, 1.0)]);
        assert!(!g.is_connected());
        assert!(Graph::from_edges(1, &[]).is_connected());
        assert!(Graph::from_edges(0, &[]).is_connected());
    }

    #[test]
    fn from_edges_matches_the_hashmap_reference() {
        // Bit-identity pin for the HashMap → BTreeMap swap: a reference
        // dedup with the old semantics (hash map keyed by (min,max),
        // keep-min weight, then sort by (u,v)) must produce the same
        // edge list bit for bit, on a messy input with duplicates,
        // self-loops and both orientations.
        let raw: Vec<(u32, u32, f64)> = vec![
            (4, 1, 0.75),
            (1, 4, 0.5),
            (2, 2, 9.0),
            (0, 3, 1.25),
            (3, 0, 2.0),
            (5, 0, 0.125),
            (1, 4, 1.0),
            (4, 5, 3.5),
        ];
        let mut reference: std::collections::HashMap<(u32, u32), f64> =
            std::collections::HashMap::new();
        for &(u, v, w) in &raw {
            if u == v {
                continue;
            }
            let key = (u.min(v), u.max(v));
            let e = reference.entry(key).or_insert(w);
            if w < *e {
                *e = w;
            }
        }
        let mut want: Vec<(u32, u32, f64)> =
            reference.into_iter().map(|((u, v), w)| (u, v, w)).collect();
        want.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let g = Graph::from_edges(6, &raw);
        assert_eq!(g.edges().len(), want.len());
        for (got, exp) in g.edges().iter().zip(&want) {
            assert_eq!((got.0, got.1), (exp.0, exp.1));
            assert_eq!(got.2.to_bits(), exp.2.to_bits(), "weights must match bit for bit");
        }
    }

    #[test]
    #[should_panic]
    fn rejects_nonpositive_weight() {
        Graph::from_edges(2, &[(0, 1, 0.0)]);
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_range() {
        Graph::from_edges(2, &[(0, 5, 1.0)]);
    }
}
