//! Synthetic TU-style graph-classification datasets — the offline
//! substitute for MUTAG / D&D / REDDIT / IMDB / COLLAB etc. (§4.2,
//! Table 2/3/4).
//!
//! Each named dataset mirrors the size statistics of its Table 2
//! namesake (graph count scaled down for CI-speed, node/edge averages
//! matched) and plants a class ↔ structure correlation that shortest-
//! path-kernel eigenfeatures can pick up: classes differ in generator
//! family and density, exactly the kind of signal the SP kernel detects
//! on the real data.

use super::generators;
use super::Graph;
use crate::ml::rng::Pcg;

/// A labelled graph dataset.
#[derive(Debug)]
pub struct GraphDataset {
    pub name: String,
    pub graphs: Vec<Graph>,
    pub labels: Vec<usize>,
    pub n_classes: usize,
}

/// Specification of a synthetic TU-style dataset.
#[derive(Clone, Debug)]
pub struct TuSpec {
    pub name: &'static str,
    /// Number of graphs to generate (scaled-down from Table 2).
    pub n_graphs: usize,
    /// Mean vertex count (± 40% jitter), per Table 2.
    pub avg_nodes: usize,
    pub n_classes: usize,
}

/// Scaled-down Table 2 statistics.
pub fn standard_specs() -> Vec<TuSpec> {
    vec![
        TuSpec { name: "MUTAG", n_graphs: 100, avg_nodes: 18, n_classes: 2 },
        TuSpec { name: "PTC-MR", n_graphs: 100, avg_nodes: 14, n_classes: 2 },
        TuSpec { name: "ENZYMES", n_graphs: 120, avg_nodes: 33, n_classes: 6 },
        TuSpec { name: "PROTEINS", n_graphs: 120, avg_nodes: 39, n_classes: 2 },
        TuSpec { name: "D&D", n_graphs: 60, avg_nodes: 120, n_classes: 2 },
        TuSpec { name: "IMDB-BINARY", n_graphs: 100, avg_nodes: 20, n_classes: 2 },
        TuSpec { name: "IMDB-MULTI", n_graphs: 120, avg_nodes: 13, n_classes: 3 },
        TuSpec { name: "REDDIT-BINARY", n_graphs: 40, avg_nodes: 200, n_classes: 2 },
        TuSpec { name: "COLLAB", n_graphs: 60, avg_nodes: 74, n_classes: 3 },
    ]
}

/// Generate one dataset from a spec. Class `c` controls the generator
/// family and density so structure carries the label.
pub fn generate(spec: &TuSpec, seed: u64) -> GraphDataset {
    let mut rng = Pcg::seed(seed ^ 0x7u64.wrapping_mul(fxhash(spec.name)));
    let mut graphs = Vec::with_capacity(spec.n_graphs);
    let mut labels = Vec::with_capacity(spec.n_graphs);
    for i in 0..spec.n_graphs {
        let label = i % spec.n_classes;
        let jitter = rng.uniform_in(0.6, 1.4);
        let n = ((spec.avg_nodes as f64 * jitter) as usize).max(6);
        let g = match label % 3 {
            // Sparse path-like (low clustering, high diameter).
            0 => generators::path_plus_random_edges(n, n / 6 + 1, &mut rng),
            // Dense ER (low diameter).
            1 => generators::erdos_renyi(n, (3.0 / n as f64).min(0.9).max(0.08), &mut rng),
            // Hub-structured BA.
            _ => generators::barabasi_albert(n.max(4), 2.min(n - 2).max(1), &mut rng),
        };
        graphs.push(g);
        labels.push(label);
    }
    GraphDataset { name: spec.name.to_string(), graphs, labels, n_classes: spec.n_classes }
}

/// The CUBES-substitute dataset (Appendix D.1 / Fig. 9): shape-graph
/// classes given by grid meshes with class-dependent aspect ratios.
pub fn cubes_like(n_graphs: usize, seed: u64) -> GraphDataset {
    let mut rng = Pcg::seed(seed);
    let mut graphs = Vec::with_capacity(n_graphs);
    let mut labels = Vec::with_capacity(n_graphs);
    let n_classes = 4;
    for i in 0..n_graphs {
        let label = i % n_classes;
        // Aspect ratio encodes the class; size jitters.
        let base = rng.range(4, 8);
        let (r, c) = match label {
            0 => (base, base),
            1 => (base, 2 * base),
            2 => (base, 3 * base),
            _ => (2 * base, 2 * base),
        };
        graphs.push(generators::grid_2d(r, c, 1.0));
        labels.push(label);
    }
    GraphDataset { name: "CUBES-like".into(), graphs, labels, n_classes }
}

fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_generate_connected_labelled_graphs() {
        for spec in standard_specs().iter().take(4) {
            let ds = generate(spec, 1);
            assert_eq!(ds.graphs.len(), spec.n_graphs);
            assert_eq!(ds.labels.len(), spec.n_graphs);
            for g in &ds.graphs {
                assert!(g.is_connected());
                assert!(g.n() >= 6);
            }
            assert!(ds.labels.iter().all(|&l| l < spec.n_classes));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = &standard_specs()[0];
        let a = generate(spec, 42);
        let b = generate(spec, 42);
        for (ga, gb) in a.graphs.iter().zip(&b.graphs) {
            assert_eq!(ga.edges(), gb.edges());
        }
    }

    #[test]
    fn classes_structurally_distinct() {
        // Sparse class should have higher average path length proxy
        // (lower density) than dense class.
        let spec = TuSpec { name: "T", n_graphs: 40, avg_nodes: 40, n_classes: 2 };
        let ds = generate(&spec, 3);
        let avg_density = |label: usize| -> f64 {
            let sel: Vec<&Graph> = ds
                .graphs
                .iter()
                .zip(&ds.labels)
                .filter(|(_, &l)| l == label)
                .map(|(g, _)| g)
                .collect();
            sel.iter().map(|g| g.m() as f64 / g.n() as f64).sum::<f64>() / sel.len() as f64
        };
        assert!(avg_density(1) > avg_density(0) * 1.1);
    }

    #[test]
    fn cubes_like_balanced() {
        let ds = cubes_like(40, 5);
        for c in 0..ds.n_classes {
            assert_eq!(ds.labels.iter().filter(|&&l| l == c).count(), 10);
        }
    }
}
