//! Minimum spanning trees. The paper's experiments (§4) all approximate
//! the graph metric by the metric of its MST, so this is the standard
//! entry point from a general graph into the tree-field integrators.

use super::union_find::UnionFind;
use super::Graph;
use crate::ftfi::error::FtfiError;
use crate::tree::Tree;

/// Kruskal's algorithm. Returns [`FtfiError::DisconnectedGraph`] when no
/// spanning tree exists; otherwise the MST as a [`Tree`] over the same
/// vertex ids.
pub fn try_minimum_spanning_tree(g: &Graph) -> Result<Tree, FtfiError> {
    if !g.is_connected() {
        return Err(FtfiError::DisconnectedGraph);
    }
    Ok(minimum_spanning_tree_unchecked(g))
}

/// Kruskal's algorithm. Requires a connected graph (panics otherwise);
/// see [`try_minimum_spanning_tree`] for the fallible variant.
pub fn minimum_spanning_tree(g: &Graph) -> Tree {
    assert!(g.is_connected(), "MST requires a connected graph");
    minimum_spanning_tree_unchecked(g)
}

fn minimum_spanning_tree_unchecked(g: &Graph) -> Tree {
    let mut edges: Vec<(u32, u32, f64)> = g.edges().to_vec();
    edges.sort_unstable_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
    let mut uf = UnionFind::new(g.n());
    let mut tree_edges = Vec::with_capacity(g.n().saturating_sub(1));
    for (u, v, w) in edges {
        if uf.union(u as usize, v as usize) {
            tree_edges.push((u, v, w));
            if tree_edges.len() + 1 == g.n() {
                break;
            }
        }
    }
    Tree::from_edges(g.n(), &tree_edges)
}

/// Total weight of the MST without materialising the tree (used by tests
/// and by the near-minimum-spanning-tree distortion experiments).
pub fn mst_weight(g: &Graph) -> f64 {
    let mut edges: Vec<(u32, u32, f64)> = g.edges().to_vec();
    edges.sort_unstable_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
    let mut uf = UnionFind::new(g.n());
    let mut total = 0.0;
    for (u, v, w) in edges {
        if uf.union(u as usize, v as usize) {
            total += w;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::ml::rng::Pcg;

    #[test]
    fn mst_of_triangle_drops_heaviest() {
        let g = Graph::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0), (0, 2, 3.0)]);
        let t = minimum_spanning_tree(&g);
        assert_eq!(t.n(), 3);
        assert!((t.total_weight() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn mst_weight_agrees_with_tree() {
        let mut rng = Pcg::seed(5);
        let g = generators::path_plus_random_edges(200, 100, &mut rng);
        let t = minimum_spanning_tree(&g);
        assert!((t.total_weight() - mst_weight(&g)).abs() < 1e-9);
    }

    #[test]
    fn mst_is_spanning() {
        let mut rng = Pcg::seed(6);
        let g = generators::path_plus_random_edges(50, 30, &mut rng);
        let t = minimum_spanning_tree(&g);
        assert_eq!(t.n(), 50);
        assert_eq!(t.edges().len(), 49);
    }

    #[test]
    fn mst_never_heavier_than_any_spanning_tree() {
        // The path itself is a spanning tree of path_plus_random_edges.
        let mut rng = Pcg::seed(7);
        let g = generators::path_plus_random_edges(80, 40, &mut rng);
        let path_weight: f64 = g
            .edges()
            .iter()
            .filter(|&&(u, v, _)| v == u + 1)
            .map(|&(_, _, w)| w)
            .sum();
        assert!(mst_weight(&g) <= path_weight + 1e-12);
    }

    #[test]
    #[should_panic]
    fn mst_rejects_disconnected() {
        let g = Graph::from_edges(4, &[(0, 1, 1.0), (2, 3, 1.0)]);
        minimum_spanning_tree(&g);
    }

    #[test]
    fn try_mst_reports_disconnected_as_error() {
        let g = Graph::from_edges(4, &[(0, 1, 1.0), (2, 3, 1.0)]);
        assert!(matches!(try_minimum_spanning_tree(&g), Err(FtfiError::DisconnectedGraph)));
        let ok = Graph::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0)]);
        assert_eq!(try_minimum_spanning_tree(&ok).unwrap().n(), 3);
    }
}
