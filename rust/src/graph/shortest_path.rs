//! Shortest paths: Dijkstra (binary heap) for weighted graphs, BFS for
//! unit weights, single-source on trees in linear time, and all-pairs
//! helpers used by the brute-force baselines (BGFI/BTFI) and by dataset
//! featurisation.

use super::Graph;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Heap entry ordered by min distance.
#[derive(PartialEq)]
struct HeapItem {
    dist: f64,
    node: u32,
}

impl Eq for HeapItem {}

impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap; dist is never NaN.
        other.dist.partial_cmp(&self.dist).unwrap()
    }
}

impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Single-source shortest-path distances via Dijkstra.
/// Unreachable vertices get `f64::INFINITY`.
pub fn dijkstra(g: &Graph, source: usize) -> Vec<f64> {
    let mut dist = vec![f64::INFINITY; g.n()];
    let mut heap = BinaryHeap::new();
    dist[source] = 0.0;
    heap.push(HeapItem { dist: 0.0, node: source as u32 });
    while let Some(HeapItem { dist: d, node }) = heap.pop() {
        let v = node as usize;
        if d > dist[v] {
            continue; // stale entry
        }
        for (u, w) in g.neighbors(v) {
            let nd = d + w;
            if nd < dist[u as usize] {
                dist[u as usize] = nd;
                heap.push(HeapItem { dist: nd, node: u });
            }
        }
    }
    dist
}

/// BFS hop counts (treats every edge as weight 1). `usize::MAX` when
/// unreachable.
pub fn bfs_hops(g: &Graph, source: usize) -> Vec<usize> {
    let mut dist = vec![usize::MAX; g.n()];
    let mut queue = std::collections::VecDeque::new();
    dist[source] = 0;
    queue.push_back(source);
    while let Some(v) = queue.pop_front() {
        for (u, _) in g.neighbors(v) {
            if dist[u as usize] == usize::MAX {
                dist[u as usize] = dist[v] + 1;
                queue.push_back(u as usize);
            }
        }
    }
    dist
}

/// All-pairs shortest paths as a dense `n×n` row-major buffer (row i =
/// distances from i). O(n · m log n): one Dijkstra per source. This is the
/// `O(N²)`+ preprocessing step the paper's brute-force baselines pay.
pub fn all_pairs(g: &Graph) -> Vec<f64> {
    let n = g.n();
    let mut out = vec![0.0; n * n];
    for s in 0..n {
        let d = dijkstra(g, s);
        out[s * n..(s + 1) * n].copy_from_slice(&d);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn weighted_square() -> Graph {
        // 0-1 (1), 1-2 (2), 2-3 (1), 3-0 (5): shortest 0→3 goes around.
        Graph::from_edges(4, &[(0, 1, 1.0), (1, 2, 2.0), (2, 3, 1.0), (3, 0, 5.0)])
    }

    #[test]
    fn dijkstra_prefers_cheaper_path() {
        let d = dijkstra(&weighted_square(), 0);
        assert_eq!(d, vec![0.0, 1.0, 3.0, 4.0]);
    }

    #[test]
    fn dijkstra_unreachable_is_inf() {
        let g = Graph::from_edges(3, &[(0, 1, 1.0)]);
        let d = dijkstra(&g, 0);
        assert!(d[2].is_infinite());
    }

    #[test]
    fn bfs_counts_hops() {
        let d = bfs_hops(&weighted_square(), 0);
        assert_eq!(d, vec![0, 1, 2, 1]);
    }

    #[test]
    fn all_pairs_symmetric() {
        let g = weighted_square();
        let ap = all_pairs(&g);
        let n = g.n();
        for i in 0..n {
            assert_eq!(ap[i * n + i], 0.0);
            for j in 0..n {
                assert!((ap[i * n + j] - ap[j * n + i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn all_pairs_triangle_inequality() {
        let g = weighted_square();
        let ap = all_pairs(&g);
        let n = g.n();
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    assert!(ap[i * n + j] <= ap[i * n + k] + ap[k * n + j] + 1e-12);
                }
            }
        }
    }
}
