//! Procedural 3-D point clouds + ε-neighbourhood graphs — the ModelNet10
//! substitute (Appendix D.1).
//!
//! Ten parametric solid families (one per "class"), sampled on their
//! surfaces with noise. The classification pipeline builds an ε-graph per
//! cloud, takes its MST, runs FTFI with the chosen `f`, and featurises by
//! the smallest kernel eigenvalues (same recipe as the TU experiments).

use super::Graph;
use crate::ml::rng::Pcg;

/// A labelled point cloud.
#[derive(Clone, Debug)]
pub struct PointCloud {
    pub points: Vec<[f64; 3]>,
    pub label: usize,
}

/// The ten parametric families standing in for ModelNet10's classes.
pub const N_CLASSES: usize = 10;

/// Sample one cloud of class `label` (0..10) with `n` points.
pub fn sample_cloud(label: usize, n: usize, noise: f64, rng: &mut Pcg) -> PointCloud {
    assert!(label < N_CLASSES);
    let mut points = Vec::with_capacity(n);
    for _ in 0..n {
        let u = rng.uniform_in(0.0, std::f64::consts::TAU);
        let v = rng.uniform_in(-1.0, 1.0);
        let t = rng.uniform();
        let p: [f64; 3] = match label {
            // 0: sphere
            0 => {
                let s = (1.0 - v * v).sqrt();
                [s * u.cos(), s * u.sin(), v]
            }
            // 1: cylinder (side)
            1 => [u.cos(), u.sin(), 2.0 * v],
            // 2: torus
            2 => {
                let w = std::f64::consts::TAU * t;
                [(1.0 + 0.35 * w.cos()) * u.cos(), (1.0 + 0.35 * w.cos()) * u.sin(), 0.35 * w.sin()]
            }
            // 3: cone
            3 => {
                let h = t;
                [(1.0 - h) * u.cos(), (1.0 - h) * u.sin(), 2.0 * h - 1.0]
            }
            // 4: cube surface
            4 => {
                let face = rng.below(6);
                let (a, b) = (rng.uniform_in(-1.0, 1.0), rng.uniform_in(-1.0, 1.0));
                match face {
                    0 => [1.0, a, b],
                    1 => [-1.0, a, b],
                    2 => [a, 1.0, b],
                    3 => [a, -1.0, b],
                    4 => [a, b, 1.0],
                    _ => [a, b, -1.0],
                }
            }
            // 5: helix tube
            5 => {
                let s = 3.0 * std::f64::consts::TAU * t;
                [0.8 * s.cos() + 0.1 * u.cos(), 0.8 * s.sin() + 0.1 * u.sin(), s / 6.0 - 1.5]
            }
            // 6: two parallel planes
            6 => [rng.uniform_in(-1.0, 1.0), rng.uniform_in(-1.0, 1.0), if rng.bool(0.5) { 0.5 } else { -0.5 }],
            // 7: cross of three bars
            7 => {
                let axis = rng.below(3);
                let long = rng.uniform_in(-1.5, 1.5);
                let (a, b) = (rng.uniform_in(-0.2, 0.2), rng.uniform_in(-0.2, 0.2));
                match axis {
                    0 => [long, a, b],
                    1 => [a, long, b],
                    _ => [a, b, long],
                }
            }
            // 8: paraboloid bowl
            8 => {
                let r = t.sqrt();
                [r * u.cos(), r * u.sin(), r * r - 0.5]
            }
            // 9: figure-eight sheet
            _ => {
                let w = std::f64::consts::TAU * t;
                [(0.8 + 0.3 * (2.0 * w).cos()) * w.cos(), (0.8 + 0.3 * (2.0 * w).cos()) * w.sin(), v * 0.4]
            }
        };
        points.push([
            p[0] + noise * rng.normal(),
            p[1] + noise * rng.normal(),
            p[2] + noise * rng.normal(),
        ]);
    }
    PointCloud { points, label }
}

/// Sample a balanced dataset: `per_class` clouds of `n` points each.
pub fn sample_dataset(per_class: usize, n: usize, noise: f64, rng: &mut Pcg) -> Vec<PointCloud> {
    let mut out = Vec::with_capacity(per_class * N_CLASSES);
    for label in 0..N_CLASSES {
        for _ in 0..per_class {
            out.push(sample_cloud(label, n, noise, rng));
        }
    }
    out
}

/// Build an ε-neighbourhood graph (edges between points within `eps`),
/// patched to connectivity with nearest-neighbour links between
/// components when necessary (clouds must be connected for the MST).
pub fn epsilon_graph(cloud: &PointCloud, eps: f64) -> Graph {
    let n = cloud.points.len();
    let d2 = |a: &[f64; 3], b: &[f64; 3]| -> f64 {
        (a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2) + (a[2] - b[2]).powi(2)
    };
    let mut edges: Vec<(u32, u32, f64)> = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            let dd = d2(&cloud.points[i], &cloud.points[j]);
            if dd <= eps * eps {
                edges.push((i as u32, j as u32, dd.sqrt().max(1e-9)));
            }
        }
    }
    let mut g = Graph::from_edges(n, &edges);
    // Patch components together with their mutual nearest pairs.
    while !g.is_connected() {
        let comp = components(&g);
        // Find the closest cross-component pair (O(n²) — fine at our sizes).
        let mut best = (0u32, 0u32, f64::INFINITY);
        for i in 0..n {
            for j in (i + 1)..n {
                if comp[i] != comp[j] {
                    let dd = d2(&cloud.points[i], &cloud.points[j]);
                    if dd < best.2 {
                        best = (i as u32, j as u32, dd);
                    }
                }
            }
        }
        edges.push((best.0, best.1, best.2.sqrt().max(1e-9)));
        g = Graph::from_edges(n, &edges);
    }
    g
}

fn components(g: &Graph) -> Vec<usize> {
    let n = g.n();
    let mut comp = vec![usize::MAX; n];
    let mut next = 0;
    for s in 0..n {
        if comp[s] != usize::MAX {
            continue;
        }
        let mut stack = vec![s];
        comp[s] = next;
        while let Some(v) = stack.pop() {
            for (u, _) in g.neighbors(v) {
                if comp[u as usize] == usize::MAX {
                    comp[u as usize] = next;
                    stack.push(u as usize);
                }
            }
        }
        next += 1;
    }
    comp
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clouds_have_requested_shape() {
        let mut rng = Pcg::seed(1);
        for label in 0..N_CLASSES {
            let c = sample_cloud(label, 64, 0.01, &mut rng);
            assert_eq!(c.points.len(), 64);
            assert_eq!(c.label, label);
        }
    }

    #[test]
    fn sphere_points_near_unit_radius() {
        let mut rng = Pcg::seed(2);
        let c = sample_cloud(0, 200, 0.0, &mut rng);
        for p in &c.points {
            let r = (p[0] * p[0] + p[1] * p[1] + p[2] * p[2]).sqrt();
            assert!((r - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn epsilon_graph_connected() {
        let mut rng = Pcg::seed(3);
        for label in [0usize, 4, 7] {
            let c = sample_cloud(label, 80, 0.02, &mut rng);
            let g = epsilon_graph(&c, 0.35);
            assert!(g.is_connected());
            assert_eq!(g.n(), 80);
        }
    }

    #[test]
    fn dataset_is_balanced() {
        let mut rng = Pcg::seed(4);
        let ds = sample_dataset(3, 32, 0.01, &mut rng);
        assert_eq!(ds.len(), 30);
        for label in 0..N_CLASSES {
            assert_eq!(ds.iter().filter(|c| c.label == label).count(), 3);
        }
    }
}
