//! Synthetic graph generators matching the paper's workloads:
//! path-plus-random-edges (§4.1 "synthetic graphs"), 2-D grids (the ViT
//! patch topology of §4.4), random trees, Erdős–Rényi /
//! Barabási–Albert / community graphs (TU-style dataset classes).

use super::Graph;
use crate::ml::rng::Pcg;
use crate::tree::Tree;

/// The §4.1 synthetic family: a weighted path `0-1-…-(n-1)` plus
/// `extra_edges` random chords; weights uniform in `(0,1)`.
pub fn path_plus_random_edges(n: usize, extra_edges: usize, rng: &mut Pcg) -> Graph {
    assert!(n >= 2);
    let mut edges: Vec<(u32, u32, f64)> = (0..n - 1)
        .map(|i| (i as u32, i as u32 + 1, rng.uniform_in(1e-3, 1.0)))
        .collect();
    let mut added = 0;
    while added < extra_edges {
        let u = rng.below(n) as u32;
        let v = rng.below(n) as u32;
        if u != v && u.abs_diff(v) != 1 {
            edges.push((u, v, rng.uniform_in(1e-3, 1.0)));
            added += 1;
        }
    }
    Graph::from_edges(n, &edges)
}

/// A `rows×cols` 2-D grid graph with the given uniform edge weight — the
/// image-patch topology used by the Topological ViT (§4.4).
pub fn grid_2d(rows: usize, cols: usize, weight: f64) -> Graph {
    let idx = |r: usize, c: usize| (r * cols + c) as u32;
    let mut edges = Vec::with_capacity(2 * rows * cols);
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                edges.push((idx(r, c), idx(r, c + 1), weight));
            }
            if r + 1 < rows {
                edges.push((idx(r, c), idx(r + 1, c), weight));
            }
        }
    }
    Graph::from_edges(rows * cols, &edges)
}

/// A uniformly random labelled tree (random Prüfer-like attachment:
/// vertex i attaches to a uniform previous vertex), weights in `(lo, hi)`.
pub fn random_tree(n: usize, lo: f64, hi: f64, rng: &mut Pcg) -> Tree {
    assert!(n >= 1);
    let edges: Vec<(u32, u32, f64)> = (1..n)
        .map(|v| (rng.below(v) as u32, v as u32, rng.uniform_in(lo, hi)))
        .collect();
    Tree::from_edges(n, &edges)
}

/// A random tree whose weights are integer multiples `e/q`, `e ∈ 1..=p`
/// — the positive-rational-weight regime of §A.2.3 where the Hankel
/// embedding applies.
pub fn random_rational_tree(n: usize, p: u32, q: u32, rng: &mut Pcg) -> Tree {
    assert!(n >= 1 && p >= 1 && q >= 1);
    let edges: Vec<(u32, u32, f64)> = (1..n)
        .map(|v| {
            let e = rng.range(1, p as usize + 1) as f64;
            (rng.below(v) as u32, v as u32, e / q as f64)
        })
        .collect();
    Tree::from_edges(n, &edges)
}

/// Erdős–Rényi G(n, p) conditioned on connectivity (retries with a path
/// patch if disconnected), unit-ish weights jittered for MST uniqueness.
pub fn erdos_renyi(n: usize, p: f64, rng: &mut Pcg) -> Graph {
    let mut edges = Vec::new();
    for u in 0..n as u32 {
        for v in (u + 1)..n as u32 {
            if rng.bool(p) {
                edges.push((u, v, rng.uniform_in(0.5, 1.5)));
            }
        }
    }
    // Patch connectivity deterministically: thread a path through any
    // disconnected remainder (cheap, keeps the degree distribution intact
    // for the bulk of the graph).
    let mut g = Graph::from_edges(n, &edges);
    if !g.is_connected() {
        for v in 1..n as u32 {
            edges.push((v - 1, v, rng.uniform_in(0.5, 1.5)));
        }
        g = Graph::from_edges(n, &edges);
    }
    g
}

/// Barabási–Albert preferential attachment with `m` edges per new vertex.
pub fn barabasi_albert(n: usize, m: usize, rng: &mut Pcg) -> Graph {
    assert!(n > m && m >= 1);
    let mut edges: Vec<(u32, u32, f64)> = Vec::new();
    // Repeated-endpoint list: sampling from it is preferential attachment.
    let mut endpoints: Vec<u32> = Vec::new();
    // Seed clique of m+1 vertices.
    for u in 0..=(m as u32) {
        for v in (u + 1)..=(m as u32) {
            edges.push((u, v, rng.uniform_in(0.5, 1.5)));
            endpoints.push(u);
            endpoints.push(v);
        }
    }
    for v in (m + 1)..n {
        // BTreeSet, not HashSet: the set is *iterated* below, and its
        // order decides both edge weights (rng draw order) and future
        // sampling (via `endpoints`). Hash iteration order is seeded per
        // instance, so the HashSet version produced a different graph on
        // every run despite the seeded Pcg; sorted iteration makes the
        // generator reproducible (pinned by `barabasi_is_deterministic`).
        let mut targets = std::collections::BTreeSet::new();
        while targets.len() < m {
            targets.insert(endpoints[rng.below(endpoints.len())]);
        }
        for &t in &targets {
            edges.push((t, v as u32, rng.uniform_in(0.5, 1.5)));
            endpoints.push(t);
            endpoints.push(v as u32);
        }
    }
    Graph::from_edges(n, &edges)
}

/// Planted-partition community graph: `k` communities, intra-community
/// edge probability `p_in`, inter `p_out`.
pub fn community_graph(n: usize, k: usize, p_in: f64, p_out: f64, rng: &mut Pcg) -> Graph {
    let mut edges = Vec::new();
    let comm: Vec<usize> = (0..n).map(|i| i % k).collect();
    for u in 0..n as u32 {
        for v in (u + 1)..n as u32 {
            let p = if comm[u as usize] == comm[v as usize] { p_in } else { p_out };
            if rng.bool(p) {
                edges.push((u, v, rng.uniform_in(0.5, 1.5)));
            }
        }
    }
    let mut g = Graph::from_edges(n, &edges);
    if !g.is_connected() {
        for v in 1..n as u32 {
            edges.push((v - 1, v, rng.uniform_in(0.5, 1.5)));
        }
        g = Graph::from_edges(n, &edges);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn barabasi_is_deterministic() {
        // Two builds from the same seed must agree bit for bit. The old
        // HashSet target buffer broke this *within one process* (each
        // set instance draws its own hasher seed, and iteration order
        // feeds the edge list and the preferential-attachment buffer).
        let a = barabasi_albert(60, 3, &mut Pcg::seed(9));
        let b = barabasi_albert(60, 3, &mut Pcg::seed(9));
        assert_eq!(a.edges().len(), b.edges().len());
        for (ea, eb) in a.edges().iter().zip(b.edges()) {
            assert_eq!((ea.0, ea.1), (eb.0, eb.1));
            assert_eq!(ea.2.to_bits(), eb.2.to_bits());
        }
        assert!(a.is_connected());
    }

    #[test]
    fn path_plus_edges_connected_with_right_count() {
        let mut rng = Pcg::seed(1);
        let g = path_plus_random_edges(100, 60, &mut rng);
        assert!(g.is_connected());
        // Duplicates may collapse, but the path backbone is always there.
        assert!(g.m() >= 99 && g.m() <= 159);
    }

    #[test]
    fn grid_shape() {
        let g = grid_2d(3, 4, 1.0);
        assert_eq!(g.n(), 12);
        assert_eq!(g.m(), 3 * 3 + 2 * 4); // horizontal + vertical
        assert!(g.is_connected());
        // Corner has degree 2, interior degree 4.
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(5), 4);
    }

    #[test]
    fn random_tree_is_tree() {
        let mut rng = Pcg::seed(2);
        let t = random_tree(500, 0.1, 1.0, &mut rng);
        assert_eq!(t.n(), 500);
        assert_eq!(t.edges().len(), 499);
    }

    #[test]
    fn rational_tree_weights_on_lattice() {
        let mut rng = Pcg::seed(3);
        let t = random_rational_tree(100, 5, 4, &mut rng);
        for &(_, _, w) in t.edges() {
            let scaled = w * 4.0;
            assert!((scaled - scaled.round()).abs() < 1e-9);
            assert!(scaled.round() >= 1.0 && scaled.round() <= 5.0);
        }
    }

    #[test]
    fn er_and_ba_connected() {
        let mut rng = Pcg::seed(4);
        assert!(erdos_renyi(80, 0.05, &mut rng).is_connected());
        assert!(barabasi_albert(80, 2, &mut rng).is_connected());
        assert!(community_graph(60, 3, 0.3, 0.02, &mut rng).is_connected());
    }

    #[test]
    fn ba_hub_structure() {
        let mut rng = Pcg::seed(5);
        let g = barabasi_albert(300, 2, &mut rng);
        let max_deg = (0..g.n()).map(|v| g.degree(v)).max().unwrap();
        // Preferential attachment produces hubs far above the mean degree (~4).
        assert!(max_deg > 12, "max_deg={max_deg}");
    }
}
