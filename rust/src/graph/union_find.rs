//! Disjoint-set union with path halving and union by rank — the substrate
//! for Kruskal MST and ε-graph component analysis.

/// Union-find over `0..n`.
#[derive(Clone, Debug)]
pub struct UnionFind {
    parent: Vec<u32>,
    rank: Vec<u8>,
    components: usize,
}

impl UnionFind {
    pub fn new(n: usize) -> Self {
        UnionFind { parent: (0..n as u32).collect(), rank: vec![0; n], components: n }
    }

    /// Representative of the set containing `x` (path halving).
    pub fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] as usize != x {
            let gp = self.parent[self.parent[x] as usize];
            self.parent[x] = gp;
            x = gp as usize;
        }
        x
    }

    /// Merge the sets containing `a` and `b`; returns true if they were
    /// previously disjoint.
    pub fn union(&mut self, a: usize, b: usize) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        let (hi, lo) = if self.rank[ra] >= self.rank[rb] { (ra, rb) } else { (rb, ra) };
        self.parent[lo] = hi as u32;
        if self.rank[hi] == self.rank[lo] {
            self.rank[hi] += 1;
        }
        self.components -= 1;
        true
    }

    /// Are `a` and `b` in the same set?
    pub fn connected(&mut self, a: usize, b: usize) -> bool {
        self.find(a) == self.find(b)
    }

    /// Number of disjoint sets.
    pub fn components(&self) -> usize {
        self.components
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_unions() {
        let mut uf = UnionFind::new(5);
        assert_eq!(uf.components(), 5);
        assert!(uf.union(0, 1));
        assert!(uf.union(1, 2));
        assert!(!uf.union(0, 2));
        assert_eq!(uf.components(), 3);
        assert!(uf.connected(0, 2));
        assert!(!uf.connected(0, 3));
    }

    #[test]
    fn everything_merges() {
        let mut uf = UnionFind::new(100);
        for i in 1..100 {
            uf.union(i - 1, i);
        }
        assert_eq!(uf.components(), 1);
        assert!(uf.connected(0, 99));
    }
}
