//! Minimal benchmark harness.
//!
//! The offline environment has no `criterion`, so the `cargo bench`
//! targets (one per paper table/figure) use this harness: warmup +
//! repeated timed runs, median/mean/std reporting, and a tiny fixed-width
//! table printer so every bench emits the same rows/series as the paper's
//! figures.

// One of the crate's two allowed `unsafe` sites (see DESIGN.md
// "Verification & static analysis"): a pass-through `GlobalAlloc` that
// counts allocations for the zero-alloc hot-path pins.
#![allow(unsafe_code)]

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::time::Instant;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// System allocator with a thread-local allocation counter — the shared
/// implementation behind the `hotpath_alloc` ablation bench and the
/// `tests/hotpath_alloc.rs` zero-allocation pins. Install per binary:
///
/// ```ignore
/// #[global_allocator]
/// static A: ftfi::bench_util::CountingAlloc = ftfi::bench_util::CountingAlloc;
/// ```
///
/// The counter is thread-local (`Cell<u64>` — no destructor, so the TLS
/// access is safe from inside the allocator even during thread
/// teardown), so measurements on one thread are never polluted by other
/// threads; the pass-through adds a few ns per allocation.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// The calling thread's allocation count so far (monotonic; compare
/// deltas around the region of interest).
pub fn thread_allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

/// Timing summary in seconds.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub median: f64,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub runs: usize,
}

impl Timing {
    pub fn format_ms(&self) -> String {
        format!("{:9.3} ms ±{:6.3}", self.median * 1e3, self.std * 1e3)
    }
}

/// Time `f` with `warmup` discarded runs and `runs` measured runs.
/// A `black_box`-style sink prevents the optimiser from deleting work.
pub fn bench<T>(warmup: usize, runs: usize, mut f: impl FnMut() -> T) -> Timing {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(runs.max(1));
    for _ in 0..runs.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(&samples)
}

/// Time a single run (for expensive preprocessing phases).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

fn summarize(samples: &[f64]) -> Timing {
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = s.len();
    let mean = s.iter().sum::<f64>() / n as f64;
    let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Timing { median: s[n / 2], mean, std: var.sqrt(), min: s[0], runs: n }
}

/// Fixed-width table printer: emits a header then rows.
pub struct Table {
    widths: Vec<usize>,
}

impl Table {
    pub fn new(headers: &[&str], widths: &[usize]) -> Self {
        assert_eq!(headers.len(), widths.len());
        let mut line = String::new();
        for (h, w) in headers.iter().zip(widths) {
            line.push_str(&format!("{:>width$}  ", h, width = w));
        }
        println!("{line}");
        println!("{}", "-".repeat(line.len()));
        Table { widths: widths.to_vec() }
    }

    pub fn row(&self, cells: &[String]) {
        let mut line = String::new();
        for (c, w) in cells.iter().zip(&self.widths) {
            line.push_str(&format!("{:>width$}  ", c, width = w));
        }
        println!("{line}");
    }
}

/// Section banner for bench output.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

/// `k` distinct vertex rows (partial Fisher–Yates over `0..n`) plus a
/// dense `n×d` delta field supported on them — the sparse-update
/// workload shape shared by the `delta_scaling` ablation, the
/// `integrate --delta-rows` CLI route and the delta test harnesses.
pub fn sparse_delta(
    n: usize,
    d: usize,
    k: usize,
    rng: &mut crate::ml::rng::Pcg,
) -> (Vec<u32>, crate::linalg::matrix::Matrix) {
    let k = k.min(n);
    let mut perm: Vec<u32> = (0..n as u32).collect();
    for i in 0..k {
        let j = i + rng.below(n - i);
        perm.swap(i, j);
    }
    perm.truncate(k);
    let mut dx = crate::linalg::matrix::Matrix::zeros(n, d);
    for &v in &perm {
        for c in 0..d {
            dx.set(v as usize, c, rng.normal());
        }
    }
    (perm, dx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_positive_timings() {
        let t = bench(1, 5, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(t.median > 0.0);
        assert!(t.min <= t.median);
        assert_eq!(t.runs, 5);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
