# Build-time compile path: JAX/Pallas model definitions lowered once to
# HLO text by aot.py. Nothing here runs at serving time — the rust
# coordinator loads the artifacts via PJRT.
