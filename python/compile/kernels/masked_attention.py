"""L1 Pallas kernel: fused masked low-rank (performer) attention.

Implements Algorithm 1's hot spot — the mask-weighted numerator and
denominator contractions — as a single Pallas kernel so the masked
attention never materialises the L×L attention matrix A = M ⊙ (Q'K'ᵀ) in
HBM: per query block only the (block, L) mask strip and the (L, m)/(L, d)
key/value panels stream through VMEM, and both the (m·d) numerator state
and the denominator accumulate in registers/VMEM scratch.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation):
  - block shapes are (BLOCK_L, ·) with the trailing dims padded to the
    (8, 128) VPU lanes; the two einsums map onto the MXU as
    (block×L)·(L×m·d) matmuls in bf16-friendly layouts;
  - `interpret=True` everywhere — the CPU PJRT plugin cannot execute
    Mosaic custom-calls, and the interpreter is bit-faithful for fp32.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of queries processed per grid step. 16 keeps the working set
# (mask strip + k/v panels + accumulators) ≈ 16·L·4B + L·(m+d)·4B — well
# under 16 MB VMEM for every shape this repo compiles (L ≤ 1024).
BLOCK_L = 16


def _masked_attention_kernel(qp_ref, kp_ref, v_ref, mask_ref, out_ref):
    """One grid step: BLOCK_L queries against all L keys."""
    qp = qp_ref[...]  # (BLOCK_L, m)
    kp = kp_ref[...]  # (L, m)
    v = v_ref[...]  # (L, d)
    mask = mask_ref[...]  # (BLOCK_L, L)
    # A-block = M ⊙ (Q'K'ᵀ) for this strip only (never the full L×L).
    a = mask * jnp.dot(qp, kp.T)  # (BLOCK_L, L)
    num = jnp.dot(a, v)  # (BLOCK_L, d) — MXU matmul
    den = jnp.sum(a, axis=1, keepdims=True)  # (BLOCK_L, 1)
    out_ref[...] = num / (den + 1e-6)


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_attention(qp, kp, v, mask, interpret=True):
    """Fused masked performer attention.

    Args:
      qp: (L, m) φ(q) features. L must be a multiple of BLOCK_L.
      kp: (L, m) φ(k) features.
      v: (L, d) values.
      mask: (L, L) mask matrix.

    Returns:
      (L, d) masked attention output (same math as
      `ref.masked_performer_attention_ref`).
    """
    L, m = qp.shape
    d = v.shape[1]
    assert L % BLOCK_L == 0, f"L={L} must be a multiple of {BLOCK_L}"
    grid = (L // BLOCK_L,)
    return pl.pallas_call(
        _masked_attention_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_L, m), lambda i: (i, 0)),
            pl.BlockSpec((L, m), lambda i: (0, 0)),
            pl.BlockSpec((L, d), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_L, L), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_L, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((L, d), qp.dtype),
        interpret=interpret,
    )(qp, kp, v, mask)
