"""Pure-jnp oracles for the masked low-rank attention kernel.

These are the correctness ground truth for the Pallas kernel
(`masked_attention.py`), and also the implementation used inside the
*training* artifact: `pallas_call` has no automatic VJP, so the train-step
HLO is lowered from this reference math (numerically identical — pytest
asserts the kernel matches to fp32 tolerance) while the inference
artifacts use the kernel.
"""

import jax.numpy as jnp


def masked_performer_attention_ref(qp, kp, v, mask):
    """General masked low-rank attention (Algorithm 1, materialised form).

    Args:
      qp: (L, m) query features φ(q_i).
      kp: (L, m) key features φ(k_j).
      v:  (L, d) values.
      mask: (L, L) mask matrix M.

    Returns:
      (L, d) attention output r_i = Σ_j M_ij·(φ(q_i)·φ(k_j))·v_j
                                   / Σ_j M_ij·(φ(q_i)·φ(k_j)).
    """
    a = mask * (qp @ kp.T)  # (L, L) masked attention matrix
    num = a @ v  # (L, d)
    den = a.sum(axis=1, keepdims=True)  # (L, 1)
    return num / (den + 1e-6)


def masked_performer_attention_alg1(qp, kp, v, mask):
    """Algorithm 1 exactly as written: never materialises A = M ⊙ (Q'K'ᵀ).

    V¹_i = vec(φ(k_i)·v_iᵀ) ∈ R^{m·d};  D̃¹ = M·V¹;  D̃² = M·φ(K);
    r_i = φ(q_i)ᵀ·devec(D̃¹_i) / φ(q_i)ᵀ·D̃²_i.
    """
    L, m = qp.shape
    d = v.shape[1]
    v1 = (kp[:, :, None] * v[:, None, :]).reshape(L, m * d)
    d1 = (mask @ v1).reshape(L, m, d)
    d2 = mask @ kp  # (L, m)
    num = jnp.einsum("lm,lmd->ld", qp, d1)
    den = jnp.einsum("lm,lm->l", qp, d2)[:, None]
    return num / (den + 1e-6)
