"""AOT lowering: JAX/Pallas → HLO **text** artifacts + initial parameters.

HLO text (never `.serialize()`): jax ≥ 0.5 emits HloModuleProto with
64-bit instruction ids which the runtime's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md and DESIGN.md).

Artifacts (under --out-dir, default ./artifacts):
  topvit_fwd_b{B}.hlo.txt   inference forward (Pallas kernel), batches 1/8
  topvit_train_b{B}.hlo.txt one SGD train step (reference math), batch 32
  topvit_init_masked.bin    flat f32 initial parameters (masked variant)
  topvit_init_unmasked.bin  … with zeroed mask parameters (baseline)
  topvit_manifest.txt       parameter names/shapes in AOT order
  sanity_matmul.hlo.txt     tiny artifact for runtime smoke tests

Usage: python -m compile.aot [--out-dir DIR]
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(fn, *example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(text):>10} chars  {path}")


def dump_params(path: str, params: list[np.ndarray]) -> None:
    flat = np.concatenate([p.ravel() for p in params]).astype("<f4")
    flat.tofile(path)
    print(f"wrote {flat.nbytes:>10} bytes  {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--fwd-batches", type=int, nargs="*", default=[1, 8])
    ap.add_argument("--train-batch", type=int, default=32)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    params = model.init_params(seed=0, masked=True)
    spec = [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in params]

    # --- inference artifacts (Pallas kernel on the hot path) ---
    for b in args.fwd_batches:
        img = jax.ShapeDtypeStruct((b, model.IMG, model.IMG), jnp.float32)

        def fwd(*xs):
            *p, images = xs
            return (model.forward(list(p), images),)

        write(
            os.path.join(args.out_dir, f"topvit_fwd_b{b}.hlo.txt"),
            to_hlo_text(fwd, *spec, img),
        )

    # --- train-step artifact (reference math; see kernels/ref.py) ---
    b = args.train_batch
    img = jax.ShapeDtypeStruct((b, model.IMG, model.IMG), jnp.float32)
    lab = jax.ShapeDtypeStruct((b,), jnp.int32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)

    def step(*xs):
        *p, images, labels, lr = xs
        return model.train_step(list(p), images, labels, lr)

    write(
        os.path.join(args.out_dir, f"topvit_train_b{b}.hlo.txt"),
        to_hlo_text(step, *spec, img, lab, lr),
    )

    # --- parameters + manifest ---
    dump_params(os.path.join(args.out_dir, "topvit_init_masked.bin"), params)
    dump_params(
        os.path.join(args.out_dir, "topvit_init_unmasked.bin"),
        model.init_params(seed=0, masked=False),
    )
    manifest = "\n".join(
        f"{name} {' '.join(map(str, shape))}" for name, shape in model.PARAM_SHAPES
    )
    write(os.path.join(args.out_dir, "topvit_manifest.txt"), manifest + "\n")

    # --- runtime smoke artifact ---
    def sanity(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    s = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    write(os.path.join(args.out_dir, "sanity_matmul.hlo.txt"), to_hlo_text(sanity, s, s))


if __name__ == "__main__":
    main()
