"""L2: TopViT-mini — a Topological Vision Transformer (§4.4) in JAX.

Architecture (scaled to the synthetic-shapes workload; the *relative*
claim of Table 1 — FTFI topological masking beats the unmasked performer
at ~3 extra parameters per layer — survives the scale-down):

  32×32×1 image → 4×4 patches → 8×8 = 64 tokens, width 64
  → `N_LAYERS` transformer blocks with **masked performer attention**
    (kernel feature map φ = elementwise exp or relu; the RPE mask is the
    f-distance matrix of the patch-grid MST with the learnable
    exponentiated-quadratic f(x) = exp(a₀ + a₁x + a₂x²) — exactly the
    3-parameter §4.4 parameterisation, `synced` across heads)
  → mean-pool → linear head (N_CLASSES).

The attention hot-spot runs through the Pallas kernel for the inference
artifacts and through the numerically identical jnp reference for the
train-step artifact (pallas_call has no automatic VJP).
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import grid
from compile.kernels.masked_attention import masked_attention
from compile.kernels.ref import masked_performer_attention_ref

# Model hyper-parameters (fixed at compile time).
IMG = 32
PATCH = 4
GRID = IMG // PATCH  # 8
L = GRID * GRID  # 64 tokens
WIDTH = 64
HEADS = 4
HEAD_DIM = WIDTH // HEADS
FEAT = 16  # performer feature dim m
MLP_HIDDEN = 128
N_LAYERS = 2
N_CLASSES = 8

# The patch-grid MST distance matrix — a compile-time constant baked into
# the HLO (the rust side never re-derives it).
MASK_DIST = jnp.asarray(grid.patch_grid_distances(GRID, GRID))

# Ordered parameter names: the AOT boundary passes parameters as a flat
# list of f32 tensors in exactly this order.
PARAM_SHAPES: list[tuple[str, tuple[int, ...]]] = (
    [("patch_w", (PATCH * PATCH, WIDTH)), ("patch_b", (WIDTH,)), ("pos", (L, WIDTH))]
    + [
        (f"blk{i}_{name}", shape)
        for i in range(N_LAYERS)
        for name, shape in [
            ("ln1_g", (WIDTH,)),
            ("ln1_b", (WIDTH,)),
            ("wq", (WIDTH, WIDTH)),
            ("wk", (WIDTH, WIDTH)),
            ("wv", (WIDTH, WIDTH)),
            ("wo", (WIDTH, WIDTH)),
            ("mask_a", (3,)),  # the 3 extra learnable RPE parameters
            ("ln2_g", (WIDTH,)),
            ("ln2_b", (WIDTH,)),
            ("mlp_w1", (WIDTH, MLP_HIDDEN)),
            ("mlp_b1", (MLP_HIDDEN,)),
            ("mlp_w2", (MLP_HIDDEN, WIDTH)),
            ("mlp_b2", (WIDTH,)),
        ]
    ]
    + [("head_w", (WIDTH, N_CLASSES)), ("head_b", (N_CLASSES,))]
)


def init_params(seed: int = 0, masked: bool = True) -> list[np.ndarray]:
    """Initial parameters (numpy, matching PARAM_SHAPES order).

    `masked=False` zeroes the mask parameters, making every mask matrix
    exp(0)=1 — i.e. the *unmasked performer baseline* shares the exact
    same artifact; the variants of Table 1 differ only in these 3·layers
    numbers.
    """
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in PARAM_SHAPES:
        if name.endswith(("_b", "ln1_b", "ln2_b")):
            out.append(np.zeros(shape, np.float32))
        elif name.endswith(("ln1_g", "ln2_g")):
            out.append(np.ones(shape, np.float32))
        elif name.endswith("mask_a"):
            # Start from a gentle locality prior exp(-0.1·x) when masked.
            a = np.array([0.0, -0.1 if masked else 0.0, 0.0], np.float32)
            out.append(a)
        elif name == "pos":
            out.append((0.02 * rng.standard_normal(shape)).astype(np.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = (2.0 / fan_in) ** 0.5
            out.append((std * rng.standard_normal(shape)).astype(np.float32))
    return out


def params_dict(flat):
    return {name: t for (name, _), t in zip(PARAM_SHAPES, flat)}


def _layer_norm(x, g, b):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-6) * g + b


def _phi(x):
    """Performer feature map φ: positive elementwise exp features with a
    max-subtraction stabiliser (the `φ := exp` column of Table 1)."""
    return jnp.exp(x - jax.lax.stop_gradient(x.max(axis=-1, keepdims=True)))


def _mask_matrix(mask_a):
    """The learnable exponentiated-quadratic f-distance mask:
    M = exp(a₀ + a₁·d + a₂·d²) on the patch-MST distances.

    For L=64 the matrix is materialised inside the HLO (4096 floats); at
    the paper's scales the identical operator is applied in polylog time
    by the rust `TreeFieldIntegrator` (ExpQuadratic is Vandermonde/
    lattice-cordial — see rust/src/ftfi/vandermonde.rs).
    """
    d = MASK_DIST
    return jnp.exp(mask_a[0] + mask_a[1] * d + mask_a[2] * d * d)


def _attention(x, p, i, use_pallas):
    """Multi-head masked performer attention for one block."""
    pd = params_dict(p)
    q = x @ pd[f"blk{i}_wq"]
    k = x @ pd[f"blk{i}_wk"]
    v = x @ pd[f"blk{i}_wv"]
    mask = _mask_matrix(pd[f"blk{i}_mask_a"])

    def one_head(qh, kh, vh):
        # Project per-head features down to FEAT dims for φ. A fixed
        # slice keeps the parameter count at the paper's "+3 per layer".
        qp = _phi(qh[:, :FEAT])
        kp = _phi(kh[:, :FEAT])
        if use_pallas:
            return masked_attention(qp, kp, vh, mask)
        return masked_performer_attention_ref(qp, kp, vh, mask)

    heads = []
    for h in range(HEADS):
        sl = slice(h * HEAD_DIM, (h + 1) * HEAD_DIM)
        heads.append(one_head(q[:, sl], k[:, sl], v[:, sl]))
    out = jnp.concatenate(heads, axis=-1)
    return out @ pd[f"blk{i}_wo"]


def forward_tokens(p, images, use_pallas):
    """images: (B, IMG, IMG) → logits (B, N_CLASSES)."""
    pd = params_dict(p)
    b = images.shape[0]
    patches = images.reshape(b, GRID, PATCH, GRID, PATCH)
    patches = patches.transpose(0, 1, 3, 2, 4).reshape(b, L, PATCH * PATCH)
    x = patches @ pd["patch_w"] + pd["patch_b"] + pd["pos"]

    def body(x1):
        for i in range(N_LAYERS):
            h = _layer_norm(x1, pd[f"blk{i}_ln1_g"], pd[f"blk{i}_ln1_b"])
            x1 = x1 + _attention(h, p, i, use_pallas)
            h = _layer_norm(x1, pd[f"blk{i}_ln2_g"], pd[f"blk{i}_ln2_b"])
            h = jax.nn.gelu(h @ pd[f"blk{i}_mlp_w1"] + pd[f"blk{i}_mlp_b1"])
            x1 = x1 + h @ pd[f"blk{i}_mlp_w2"] + pd[f"blk{i}_mlp_b2"]
        return x1

    x = jax.vmap(body)(x)
    pooled = x.mean(axis=1)
    return pooled @ pd["head_w"] + pd["head_b"]


def forward(p, images):
    """Inference entry point — uses the Pallas kernel."""
    return forward_tokens(p, images, use_pallas=True)


def forward_ref(p, images):
    """Reference forward (differentiable) — used by the train step."""
    return forward_tokens(p, images, use_pallas=False)


def loss_fn(p, images, labels):
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logits = forward_ref(p, images)
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=1)[:, 0]
    return (logz - picked).mean()


def train_step(params, images, labels, lr):
    """One SGD-with-momentum-free step: returns (new_params…, loss).

    The flat signature (no pytrees) is what keeps the AOT boundary dumb:
    the rust trainer holds a list of buffers and feeds them back each
    step.
    """
    loss, grads = jax.value_and_grad(loss_fn)(list(params), images, labels)
    new_params = [w - lr * g for w, g in zip(params, grads)]
    return (*new_params, loss)


def accuracy(p, images, labels):
    return (forward_ref(p, images).argmax(axis=-1) == labels).mean()
