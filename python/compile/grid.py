"""Patch-grid topology for the Topological ViT mask (§4.4).

The image is encoded as a 2-D grid graph over patches; the mask matrix is
an f-distance matrix on the **minimum spanning tree** of that grid. For a
unit-weight grid every spanning tree is minimal, so we use the canonical
serpentine spanning tree (deterministic, matches the rust side's
`generators::grid_2d` + Kruskal on equal weights only up to tie-breaking;
what matters for the experiments is that both sides use *a* fixed MST of
the same grid, and this module is the single source of truth for the
compiled model's mask distances).
"""

from collections import deque

import numpy as np


def grid_mst_edges(rows: int, cols: int) -> list[tuple[int, int]]:
    """A deterministic spanning tree of the rows×cols grid.

    Comb shape: the full first column plus every row — a valid MST for
    unit weights (n-1 edges, connected, all weight 1).
    """
    edges = []
    for r in range(rows - 1):
        edges.append((r * cols, (r + 1) * cols))  # spine down column 0
    for r in range(rows):
        for c in range(cols - 1):
            edges.append((r * cols + c, r * cols + c + 1))  # teeth
    assert len(edges) == rows * cols - 1
    return edges


def tree_distance_matrix(n: int, edges: list[tuple[int, int]]) -> np.ndarray:
    """All-pairs hop distances on the tree via BFS from every vertex."""
    adj: list[list[int]] = [[] for _ in range(n)]
    for u, v in edges:
        adj[u].append(v)
        adj[v].append(u)
    dist = np.zeros((n, n), dtype=np.float32)
    for s in range(n):
        seen = [False] * n
        seen[s] = True
        q = deque([(s, 0)])
        while q:
            v, d = q.popleft()
            dist[s, v] = d
            for u in adj[v]:
                if not seen[u]:
                    seen[u] = True
                    q.append((u, d + 1))
    return dist


def patch_grid_distances(rows: int, cols: int) -> np.ndarray:
    """Mask distances for a rows×cols patch grid (float32, (L, L))."""
    return tree_distance_matrix(rows * cols, grid_mst_edges(rows, cols))
