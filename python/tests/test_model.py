"""L2 correctness: TopViT-mini shapes, masking semantics, training signal."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_platform_name", "cpu")


def _params():
    return [jnp.asarray(p) for p in model.init_params(seed=0, masked=True)]


def _images(b, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((b, model.IMG, model.IMG)), jnp.float32)


def test_param_manifest_consistent():
    params = model.init_params()
    assert len(params) == len(model.PARAM_SHAPES)
    for p, (_, shape) in zip(params, model.PARAM_SHAPES):
        assert p.shape == shape
    # 3 mask parameters per layer — the paper's headline count.
    mask_params = [n for n, _ in model.PARAM_SHAPES if n.endswith("mask_a")]
    assert len(mask_params) == model.N_LAYERS


def test_forward_shapes():
    p = _params()
    for b in (1, 4):
        logits = model.forward_ref(p, _images(b))
        assert logits.shape == (b, model.N_CLASSES)
        assert bool(jnp.isfinite(logits).all())


def test_pallas_and_ref_forward_agree():
    p = _params()
    x = _images(2, seed=1)
    a = model.forward(p, x)  # pallas path
    b = model.forward_ref(p, x)  # jnp path
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_unmasked_init_gives_uniform_mask():
    p = model.init_params(masked=False)
    pd = model.params_dict(p)
    m = model._mask_matrix(jnp.asarray(pd["blk0_mask_a"]))
    np.testing.assert_allclose(np.asarray(m), 1.0, rtol=0, atol=0)


def test_mask_parameters_change_output():
    p = _params()
    x = _images(2, seed=2)
    base = np.asarray(model.forward_ref(p, x))
    pd_index = [i for i, (n, _) in enumerate(model.PARAM_SHAPES) if n == "blk0_mask_a"][0]
    p2 = list(p)
    p2[pd_index] = jnp.asarray([0.0, -1.5, 0.0], jnp.float32)
    changed = np.asarray(model.forward_ref(p2, x))
    assert np.abs(base - changed).max() > 1e-4


def test_train_step_reduces_loss():
    p = _params()
    rng = np.random.default_rng(3)
    x = _images(32, seed=3)
    y = jnp.asarray(rng.integers(0, model.N_CLASSES, 32), jnp.int32)
    lr = jnp.float32(0.05)
    l0 = model.loss_fn(list(p), x, y)
    cur = list(p)
    for _ in range(10):
        *cur, loss = model.train_step(cur, x, y, lr)
        cur = list(cur)
    assert float(loss) < float(l0), f"{float(loss)} !< {float(l0)}"


def test_gradients_flow_to_mask_params():
    p = _params()
    rng = np.random.default_rng(4)
    x = _images(8, seed=4)
    y = jnp.asarray(rng.integers(0, model.N_CLASSES, 8), jnp.int32)
    grads = jax.grad(model.loss_fn)(list(p), x, y)
    names = [n for n, _ in model.PARAM_SHAPES]
    g_mask = grads[names.index("blk0_mask_a")]
    assert float(jnp.abs(g_mask).max()) > 0.0
