"""L1 correctness: the Pallas masked-attention kernel vs the jnp oracle.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is
the core correctness signal of the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.masked_attention import BLOCK_L, masked_attention
from compile.kernels.ref import (
    masked_performer_attention_alg1,
    masked_performer_attention_ref,
)

jax.config.update("jax_platform_name", "cpu")


def random_case(rng, L, m, d, mask_kind="expdist"):
    qp = jnp.asarray(rng.uniform(0.1, 1.0, (L, m)), jnp.float32)
    kp = jnp.asarray(rng.uniform(0.1, 1.0, (L, m)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((L, d)), jnp.float32)
    if mask_kind == "ones":
        mask = jnp.ones((L, L), jnp.float32)
    elif mask_kind == "expdist":
        idx = np.arange(L)
        dist = np.abs(idx[:, None] - idx[None, :]).astype(np.float32)
        mask = jnp.asarray(np.exp(-0.1 * dist))
    else:  # random positive
        mask = jnp.asarray(rng.uniform(0.0, 1.0, (L, L)), jnp.float32)
    return qp, kp, v, mask


def test_alg1_equals_materialised_ref():
    rng = np.random.default_rng(0)
    qp, kp, v, mask = random_case(rng, 64, 16, 32)
    a = masked_performer_attention_ref(qp, kp, v, mask)
    b = masked_performer_attention_alg1(qp, kp, v, mask)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mask_kind", ["ones", "expdist", "random"])
def test_kernel_matches_ref_base_shape(mask_kind):
    rng = np.random.default_rng(1)
    qp, kp, v, mask = random_case(rng, 64, 16, 16, mask_kind)
    got = masked_attention(qp, kp, v, mask)
    want = masked_performer_attention_ref(qp, kp, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    lb=st.integers(min_value=1, max_value=6),
    m=st.integers(min_value=1, max_value=24),
    d=st.integers(min_value=1, max_value=48),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_kernel_matches_ref_shape_sweep(lb, m, d, seed):
    L = lb * BLOCK_L
    rng = np.random.default_rng(seed)
    qp, kp, v, mask = random_case(rng, L, m, d, "random")
    got = masked_attention(qp, kp, v, mask)
    want = masked_performer_attention_ref(qp, kp, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_kernel_rejects_unaligned_L():
    rng = np.random.default_rng(2)
    qp, kp, v, mask = random_case(rng, 60, 8, 8)
    with pytest.raises(AssertionError):
        masked_attention(qp, kp, v, mask)


def test_unmasked_equals_plain_performer():
    """M ≡ 1 must reduce to the ordinary performer normalisation."""
    rng = np.random.default_rng(3)
    qp, kp, v, mask = random_case(rng, 64, 8, 8, "ones")
    got = np.asarray(masked_attention(qp, kp, v, mask))
    att = np.asarray(qp) @ np.asarray(kp).T
    want = att @ np.asarray(v) / (att.sum(1, keepdims=True) + 1e-6)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_mask_actually_masks():
    """A block-diagonal 0/1 mask must stop cross-block attention."""
    rng = np.random.default_rng(4)
    L, m, d = 32, 4, 4
    qp = jnp.asarray(rng.uniform(0.1, 1.0, (L, m)), jnp.float32)
    kp = jnp.asarray(rng.uniform(0.1, 1.0, (L, m)), jnp.float32)
    # Values constant within each half: output must equal that constant.
    v = np.zeros((L, d), np.float32)
    v[: L // 2] = 1.0
    v[L // 2 :] = -1.0
    mask = np.zeros((L, L), np.float32)
    mask[: L // 2, : L // 2] = 1.0
    mask[L // 2 :, L // 2 :] = 1.0
    out = np.asarray(masked_attention(qp, kp, jnp.asarray(v), jnp.asarray(mask)))
    np.testing.assert_allclose(out[: L // 2], 1.0, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(out[L // 2 :], -1.0, rtol=1e-4, atol=1e-4)


def test_dtype_bfloat16_runs():
    rng = np.random.default_rng(5)
    qp, kp, v, mask = random_case(rng, 32, 8, 8)
    got = masked_attention(
        qp.astype(jnp.bfloat16),
        kp.astype(jnp.bfloat16),
        v.astype(jnp.bfloat16),
        mask.astype(jnp.bfloat16),
    )
    want = masked_performer_attention_ref(qp, kp, v, mask)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=0.1, atol=0.1
    )
